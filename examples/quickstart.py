"""Quickstart: one Mix2FLD round, end to end, in under a minute on CPU.

Shows the whole pipeline of Algorithm 1:
  1. devices mix up seed samples (eq. 6) and upload them with their
     per-label average outputs (eq. 2) over the fading uplink,
  2. the server inversely mixes the seeds (eq. 7 / Prop. 1), builds
     G_out, and runs the output-to-model conversion (eq. 5),
  3. devices download the converted global model (FL-style downlink).

Seed collection (steps 1-2) is fully batched over the device axis and
runs the inverse-Mixup through the Pallas kernel — architecture and
D-scaling knobs are documented in docs/seed_pipeline.md.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.channel import ChannelConfig
from repro.core.protocols import FederatedConfig, FederatedTrainer
from repro.data import partition_iid, synthetic_images
from repro.models.cnn import CNN


def main():
    key = jax.random.PRNGKey(0)
    x, y = synthetic_images(key, 3500)
    dev_x, dev_y = partition_iid(x[:2500], y[:2500], 5, 500, 10)
    test_x, test_y = jnp.asarray(x[2500:]), jnp.asarray(y[2500:])

    fc = FederatedConfig(protocol="mix2fld", num_devices=5, local_iters=60,
                         local_batch=32, server_iters=60, max_rounds=2)
    ch = ChannelConfig(num_devices=5)  # paper's asymmetric 23/40 dBm
    trainer = FederatedTrainer(CNN(), fc, ch)
    h = trainer.run(dev_x, dev_y, test_x, test_y, log=print)

    meta = h["seeds"]  # lightweight metadata; arrays via keep_seed_arrays
    print(f"\nuploaded mixed-up seeds : {meta['n_uploaded']}")
    print(f"inversely mixed-up seeds: {meta['n_train']} "
          f"(augmented, hard labels={meta['hard_labels']})")
    print(f"label-cycle histogram   : {meta['cycle_hist']}")
    print(f"accuracy after {fc.max_rounds} rounds: {h['acc'][-1]:.3f}")


if __name__ == "__main__":
    main()
