"""End-to-end LM training driver: Mix2FLD at language-model scale.

Trains a reduced (default ~25M param; ``--preset 100m`` for the ~100M
deliverable config) qwen2-style model across 2 simulated pods with the
full protocol loop: pod-local SGD steps with the KD-regularised loss,
periodic FD uplink (per-bucket average output tables), server output-to-
model conversion, FL downlink broadcast.

Run: PYTHONPATH=src python examples/train_lm_mix2fld.py --steps 60
     PYTHONPATH=src python examples/train_lm_mix2fld.py --preset 100m \
         --steps 300   # the ~100M/few-hundred-steps configuration
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--mode", "lm"] + sys.argv[1:]
    main()
