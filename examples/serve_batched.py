"""Batched serving example: prefill + KV-cache greedy decode on any
assigned architecture (smoke-size on CPU).

Run: PYTHONPATH=src python examples/serve_batched.py --arch qwen3-14b
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
