"""Reproduce Fig. 2: FL vs FD vs MixFLD vs Mix2FLD learning curves under
asymmetric and symmetric channels (IID + non-IID).

Run: PYTHONPATH=src python examples/paper_fig2.py [--quick]
Full run writes benchmarks/results/protocols_fig2.json.
"""
import argparse
import sys

sys.path.insert(0, ".")  # allow `benchmarks` import when run from repo root

from benchmarks.bench_protocols import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    res = run(quick=args.quick)
    print("\n=== final accuracies ===")
    for k, v in sorted(res.items()):
        print(f"{k:28s} acc={v['acc'][-1]:.3f} "
              f"rounds_converged={v['converged_round']} "
              f"uplink_ok={v['uplink_ok']}")


if __name__ == "__main__":
    main()
