"""The name registries: ONE source of truth for protocol, model and
task names.

Every layer that dispatches on a protocol name — the payload accounting
in ``channel.payload``, the round bodies in ``core.protocols``, the
sweep-axis validation in ``sweep.axes`` — imports this module, so the
set of valid names (and the ValueError an invalid name raises) cannot
drift between layers.  Historically it did: ``payload_bits`` accepted
``"mixfld"`` while docs and the ROADMAP spelled the same protocol
``"mix2fd"`` (uplink Mixup, FD-style upload, no inverse-Mixup), and an
unknown name raised a bare ``ValueError(protocol)`` in one layer and a
descriptive one in another.

``canonical_protocol`` resolves aliases and is the single gate: all
registered spellings work everywhere, all unknown names fail everywhere
with the same message listing the valid set.  ``canonical_model`` /
``canonical_task`` apply the identical contract to the model and task
registries (construction lives in ``repro.models.registry`` and
``repro.data.pipeline``; this module only owns the *names* so it stays
import-light and cycle-free).
"""
from __future__ import annotations

#: Canonical protocol names, in the paper's presentation order.
PROTOCOLS = ("fl", "fd", "fld", "mixfld", "mix2fld")

#: Alternate spellings -> canonical name.  "mix2fd" is the ROADMAP's
#: spelling of the one-way-Mixup FLD variant ("mixfld" in the paper's
#: tables): Mixup'd samples cross the uplink FD-style, but no two-way
#: inverse-Mixup happens server-side.
PROTOCOL_ALIASES = {"mix2fd": "mixfld"}

#: Protocols that upload (mixed) seed samples on the first round and run
#: the eq. (5) output-to-model conversion server-side.
FLD_FAMILY = ("fld", "mixfld", "mix2fld")


def canonical_protocol(name: str) -> str:
    """Resolve ``name`` (canonical or alias) to its canonical protocol
    name; unknown names raise the one shared ValueError listing the
    registered set."""
    if name in PROTOCOLS:
        return name
    alias = PROTOCOL_ALIASES.get(name)
    if alias is not None:
        return alias
    raise ValueError(
        f"unknown protocol {name!r}; one of {PROTOCOLS} "
        f"(aliases: {PROTOCOL_ALIASES})")


#: Canonical single-architecture model names.  Composite specs join
#: these with "+" ("cnn+mlp+transformer") and are parsed by
#: ``repro.models.registry.parse_model`` into a heterogeneous cohort
#: assignment; this tuple only names the atoms.
MODELS = ("cnn", "mlp", "transformer")

#: Alternate spellings -> canonical model name.
MODEL_ALIASES = {"conv": "cnn", "paper_cnn": "cnn", "tf": "transformer"}


def canonical_model(name: str) -> str:
    """Resolve ``name`` (canonical or alias) to its canonical
    single-architecture model name; unknown names raise the one shared
    ValueError listing the registered set.  Composite "+"-joined specs
    are handled one atom at a time by ``parse_model``."""
    if name in MODELS:
        return name
    alias = MODEL_ALIASES.get(name)
    if alias is not None:
        return alias
    raise ValueError(
        f"unknown model {name!r}; one of {MODELS} "
        f"(aliases: {MODEL_ALIASES})")


#: Canonical task names.  Each names a procedurally generated workload
#: with a real dataset's shape/class-count/payload-width (the container
#: is offline): 28x28x1 digits, 32x32x3 CIFAR-shaped images, and a
#: speech-commands-shaped (frames x mels) log-mel audio task.
TASKS = ("digits", "cifar", "speech")

#: Alternate spellings -> canonical task name.
TASK_ALIASES = {"mnist": "digits", "cifar10": "cifar",
                "speech_commands": "speech"}


def canonical_task(name: str) -> str:
    """Resolve ``name`` (canonical or alias) to its canonical task name;
    unknown names raise the one shared ValueError listing the registered
    set."""
    if name in TASKS:
        return name
    alias = TASK_ALIASES.get(name)
    if alias is not None:
        return alias
    raise ValueError(
        f"unknown task {name!r}; one of {TASKS} "
        f"(aliases: {TASK_ALIASES})")
