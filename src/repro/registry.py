"""The protocol registry: ONE source of truth for protocol names.

Every layer that dispatches on a protocol name — the payload accounting
in ``channel.payload``, the round bodies in ``core.protocols``, the
sweep-axis validation in ``sweep.axes`` — imports this module, so the
set of valid names (and the ValueError an invalid name raises) cannot
drift between layers.  Historically it did: ``payload_bits`` accepted
``"mixfld"`` while docs and the ROADMAP spelled the same protocol
``"mix2fd"`` (uplink Mixup, FD-style upload, no inverse-Mixup), and an
unknown name raised a bare ``ValueError(protocol)`` in one layer and a
descriptive one in another.

``canonical_protocol`` resolves aliases and is the single gate: all
registered spellings work everywhere, all unknown names fail everywhere
with the same message listing the valid set.
"""
from __future__ import annotations

#: Canonical protocol names, in the paper's presentation order.
PROTOCOLS = ("fl", "fd", "fld", "mixfld", "mix2fld")

#: Alternate spellings -> canonical name.  "mix2fd" is the ROADMAP's
#: spelling of the one-way-Mixup FLD variant ("mixfld" in the paper's
#: tables): Mixup'd samples cross the uplink FD-style, but no two-way
#: inverse-Mixup happens server-side.
PROTOCOL_ALIASES = {"mix2fd": "mixfld"}

#: Protocols that upload (mixed) seed samples on the first round and run
#: the eq. (5) output-to-model conversion server-side.
FLD_FAMILY = ("fld", "mixfld", "mix2fld")


def canonical_protocol(name: str) -> str:
    """Resolve ``name`` (canonical or alias) to its canonical protocol
    name; unknown names raise the one shared ValueError listing the
    registered set."""
    if name in PROTOCOLS:
        return name
    alias = PROTOCOL_ALIASES.get(name)
    if alias is not None:
        return alias
    raise ValueError(
        f"unknown protocol {name!r}; one of {PROTOCOLS} "
        f"(aliases: {PROTOCOL_ALIASES})")
