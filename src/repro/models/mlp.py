"""Feed-forward blocks: SwiGLU (silu) and plain GELU MLP (whisper) —
plus :class:`MLPClassifier`, the registry's flatten->ReLU-stack
federated client model."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import act_fn, dense_init, dtype_of


def init_mlp(cfg, key, d_ff=None):
    dt = dtype_of(cfg)
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_act == "gelu":
        return {"w1": dense_init(k1, D, F, dt), "w2": dense_init(k2, F, D, dt)}
    return {
        "w1": dense_init(k1, D, F, dt),   # up
        "w3": dense_init(k3, D, F, dt),   # gate
        "w2": dense_init(k2, F, D, dt),   # down
    }


def mlp(cfg, p, x):
    act = act_fn(cfg.mlp_act)
    if "w3" in p:  # SwiGLU
        return (act(x @ p["w3"]) * (x @ p["w1"])) @ p["w2"]
    return act(x @ p["w1"]) @ p["w2"]


class MLPClassifier:
    """Flatten -> ReLU hidden stack -> logits; same .init/.apply contract
    as :class:`repro.models.cnn.CNN` so the federated round bodies treat
    architectures interchangeably."""

    def __init__(self, num_classes: int, input_shape: tuple,
                 hidden: tuple = (64, 64)):
        self.num_classes = num_classes
        self.input_shape = tuple(int(s) for s in input_shape)
        self.hidden = tuple(int(h) for h in hidden)
        self.dims = (math.prod(self.input_shape), *self.hidden, num_classes)

    def init(self, key):
        # str-keyed (not a list) so the param tree round-trips through
        # the path-flattening checkpoint package unchanged
        keys = jax.random.split(key, len(self.dims) - 1)
        params = {}
        for i, (k, fan_in, fan_out) in enumerate(
                zip(keys, self.dims[:-1], self.dims[1:])):
            w = jax.random.normal(k, (fan_in, fan_out), jnp.float32)
            params[f"layer{i}"] = {
                "w": w / jnp.sqrt(fan_in),
                "b": jnp.zeros((fan_out,), jnp.float32)}
        return params

    def apply(self, params, x):
        """x: (B, *input_shape) -> logits (B, num_classes)."""
        if tuple(x.shape[1:]) != self.input_shape:
            raise ValueError(
                f"MLPClassifier built for input shape {self.input_shape} "
                f"but got a batch of shape {tuple(x.shape[1:])}")
        h = x.reshape(x.shape[0], -1)
        n = len(self.dims) - 1
        for i in range(n - 1):
            layer = params[f"layer{i}"]
            h = jax.nn.relu(h @ layer["w"] + layer["b"])
        last = params[f"layer{n - 1}"]
        return h @ last["w"] + last["b"]

    def num_params(self, params) -> int:
        return sum(p.size for p in jax.tree.leaves(params))
