"""Feed-forward blocks: SwiGLU (silu) and plain GELU MLP (whisper)."""
from __future__ import annotations

import jax

from .layers import act_fn, dense_init, dtype_of


def init_mlp(cfg, key, d_ff=None):
    dt = dtype_of(cfg)
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_act == "gelu":
        return {"w1": dense_init(k1, D, F, dt), "w2": dense_init(k2, F, D, dt)}
    return {
        "w1": dense_init(k1, D, F, dt),   # up
        "w3": dense_init(k3, D, F, dt),   # gate
        "w2": dense_init(k2, F, D, dt),   # down
    }


def mlp(cfg, p, x):
    act = act_fn(cfg.mlp_act)
    if "w3" in p:  # SwiGLU
        return (act(x @ p["w3"]) * (x @ p["w1"])) @ p["w2"]
    return act(x @ p["w1"]) @ p["w2"]
