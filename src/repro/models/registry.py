"""Model registry: construction behind the ``repro.registry`` model names.

``parse_model`` turns a model spec string into a :class:`ModelSpec`
value object — either a single architecture (``"cnn"``) or a "+"-joined
heterogeneous cohort spec (``"cnn+mlp+transformer"``) whose parts are
assigned to devices round-robin by :meth:`ModelSpec.partition`.  The
FIRST part is the *global* (server-side) architecture: FD-family
protocols aggregate per-label output averages, so any client
architecture can feed the eq. (2) merge, but the converted global model
and the FLD downlink parameters live in exactly one parameter space.

Name validation (aliases + the shared ValueError) lives in
``repro.registry.canonical_model``; unknown atoms fail there with the
same message in every layer.  Classifiers share one contract:
``model.init(key) -> params`` pytree, ``model.apply(params, x (B,
*input_shape)) -> logits (B, num_classes)``, plus ``input_shape`` /
``num_classes`` attributes the serving endpoint derives its batch shape
from.
"""
from __future__ import annotations

import dataclasses

from ..registry import MODELS, canonical_model
from .cnn import CNN
from .mlp import MLPClassifier
from .transformer import TransformerClassifier


def build_model(name: str, input_shape, num_classes: int):
    """Construct one registered classifier for a task geometry."""
    name = canonical_model(name)
    if name == "cnn":
        return CNN(num_classes, tuple(input_shape))
    if name == "mlp":
        return MLPClassifier(num_classes, tuple(input_shape))
    return TransformerClassifier(num_classes, tuple(input_shape))


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A parsed model spec: one or more canonical architecture names.

    ``parts[0]`` is the global/server architecture; ``partition``
    assigns parts to devices round-robin, so a ``"cnn+mlp"`` cohort of 4
    devices trains (cnn, mlp, cnn, mlp)."""
    parts: tuple

    @property
    def name(self) -> str:
        return "+".join(self.parts)

    @property
    def mixed(self) -> bool:
        return len(self.parts) > 1

    def partition(self, num_devices: int) -> tuple:
        """Per-device architecture names, cycling through ``parts``."""
        return tuple(self.parts[d % len(self.parts)]
                     for d in range(num_devices))

    def build(self, input_shape, num_classes: int):
        """Construct the global (server-side) architecture."""
        return build_model(self.parts[0], input_shape, num_classes)


def parse_model(spec: str) -> ModelSpec:
    """Parse ``"cnn"`` or ``"cnn+mlp+transformer"`` into a
    :class:`ModelSpec`; each atom resolves through ``canonical_model``
    (same ValueError contract as ``canonical_protocol``).  A composite
    whose atoms are all identical collapses to the single architecture.
    """
    if isinstance(spec, ModelSpec):
        return spec
    parts = tuple(canonical_model(p) for p in str(spec).split("+"))
    if len(set(parts)) == 1:
        parts = parts[:1]
    return ModelSpec(parts)


__all__ = ["MODELS", "ModelSpec", "build_model", "parse_model"]
