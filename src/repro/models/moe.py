"""Mixture-of-Experts layer, GShard-style grouped dense dispatch.

Tokens are reshaped into groups of ``moe_group_size``; within each group a
capacity-limited one-hot dispatch/combine einsum routes tokens to experts.
The group axis shards over the (pod, data) mesh axes and the expert axis
over ``model`` — the expert all-to-all then emerges from GSPMD.

Shared experts (DeepSeek-V2 / Qwen2-MoE style) run as a fused dense SwiGLU
over all tokens.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init, dtype_of


def init_moe(cfg, key):
    dt = dtype_of(cfg)
    D, E, F = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    scale = 1.0 / math.sqrt(D)
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),  # router in fp32
        "w1": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * scale).astype(dt),
        "w3": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * scale).astype(dt),
        "w2": (jax.random.normal(ks[3], (E, F, D), jnp.float32) / math.sqrt(F)).astype(dt),
    }
    if cfg.num_shared_experts:
        Fs = cfg.num_shared_experts * cfg.moe_d_ff
        p["shared"] = {
            "w1": dense_init(ks[4], D, Fs, dt),
            "w3": dense_init(ks[5], D, Fs, dt),
            "w2": dense_init(jax.random.fold_in(ks[4], 7), Fs, D, dt),
        }
    return p


def capacity(cfg, group_size: int) -> int:
    c = int(math.ceil(group_size * cfg.top_k * cfg.capacity_factor
                      / cfg.num_experts))
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_ffn(cfg, p, x, constrain=None):
    """x: (B, S, D) -> (y, aux_loss).  ``constrain`` optionally applies
    sharding constraints to the dispatched tensors (set by launch.sharding).
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    Sg = min(cfg.moe_group_size, T)
    while T % Sg:  # largest group size <= moe_group_size dividing T
        Sg -= 1
    G = T // Sg
    xg = x.reshape(G, Sg, D)

    # router in f32 *accumulation* without materialising f32 tokens
    # (a full astype(f32) of xg makes XLA hoist a stack-wide convert of
    # the remat-saved carries; see layers.apply_norm)
    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_idx = jax.lax.top_k(probs, K)  # (G,Sg,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Switch-style auxiliary load-balance loss.
    me = jnp.mean(probs, axis=(0, 1))                                 # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_idx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1))                                                  # (E,)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    C = capacity(cfg, Sg)
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.float32)            # (G,Sg,K,E)
    # position of each (token, k) within its expert queue, counted over
    # the flattened (Sg*K) order
    flat = onehot.reshape(G, Sg * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                             # (G,Sg*K,E)
    pos = pos.reshape(G, Sg, K, E)
    in_cap = (pos < C).astype(jnp.float32) * onehot
    pos_idx = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)        # (G,Sg,K)
    cap_oh = jax.nn.one_hot(pos_idx, C, dtype=jnp.float32)            # (G,Sg,K,C)
    # combine[g,s,e,c] = gate * kept * onehot(e) * onehot(c)
    combine = jnp.einsum("gsk,gske,gskc->gsec",
                         gate_vals, in_cap, cap_oh)                   # (G,Sg,E,C)
    if constrain is not None:
        combine = constrain(combine, "combine")
    dispatch = (combine > 0).astype(x.dtype)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)                   # (G,E,C,D)
    if constrain is not None:
        xe = constrain(xe, "dispatched")
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w3"])) * \
        jnp.einsum("gecd,edf->gecf", xe, p["w1"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w2"])                     # (G,E,C,D)
    if constrain is not None:
        ye = constrain(ye, "dispatched")
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)

    y = y.reshape(B, S, D)
    if "shared" in p:
        sp = p["shared"]
        y = y + (jax.nn.silu(x @ sp["w3"]) * (x @ sp["w1"])) @ sp["w2"]
    return y, aux
