"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

import jax.numpy as jnp

# M-RoPE: the half-dim is split into (temporal, height, width) sections.
# Fractions follow Qwen2-VL (16/24/24 of a 64 half-dim).
MROPE_FRACS = (0.25, 0.375, 0.375)


def _inv_freq(half_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, half_dim, dtype=jnp.float32) / half_dim))


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions: (B, S) int -> cos/sin (B, S, head_dim//2) float32."""
    half = head_dim // 2
    inv = _inv_freq(half, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions3, head_dim: int, theta: float):
    """positions3: (B, S, 3) int (t, h, w) -> cos/sin (B, S, head_dim//2).

    Each of the three position streams drives its own slice of the
    frequency spectrum; text-only tokens pass identical t=h=w positions,
    reducing exactly to standard RoPE.
    """
    half = head_dim // 2
    inv = _inv_freq(half, theta)
    sizes = [int(round(f * half)) for f in MROPE_FRACS]
    sizes[-1] = half - sizes[0] - sizes[1]
    ang_parts = []
    start = 0
    for sec, size in enumerate(sizes):
        p = positions3[..., sec].astype(jnp.float32)  # (B, S)
        ang_parts.append(p[..., None] * inv[start:start + size])
        start += size
    ang = jnp.concatenate(ang_parts, axis=-1)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, D); cos/sin: (B, S, D//2) — rotate-half convention.

    Rotation runs in x.dtype (cos/sin are exact to ~3 ulp in bf16); a full
    f32 upcast of q/k makes XLA materialise f32 copies of every saved
    flash-attention block (measured +5 GiB/device on deepseek train_4k).
    """
    half = x.shape[-1] // 2
    x1 = x[..., :half]
    x2 = x[..., half:]
    c = cos[:, :, None, :].astype(x.dtype)  # broadcast over heads
    s = sin[:, :, None, :].astype(x.dtype)
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)
