"""Shared primitive layers: norms, linear init, embeddings, activations."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(cfg):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg, dim: int):
    p = {"scale": jnp.ones((dim,), dtype_of(cfg))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype_of(cfg))
    return p


def apply_norm(cfg, p, x):
    """Norms with f32 *accumulation* but no materialised f32 copy of x —
    a full astype(f32) of the residual makes XLA hoist a whole-stack
    convert of the remat-saved carries out of the backward scan
    (measured: +75 GiB/device on deepseek train_4k; EXPERIMENTS.md §Perf).
    """
    D = x.shape[-1]
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
        xc = x - mu.astype(x.dtype)
        # f32 accumulation via dot_general — no materialised f32 copy
        var = jnp.einsum("...d,...d->...", xc, xc,
                         preferred_element_type=jnp.float32)[..., None] / D
        inv = jax.lax.rsqrt(var + cfg.norm_eps).astype(x.dtype)
        return xc * inv * p["scale"] + p["bias"]
    ms = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)[..., None] / D
    inv = jax.lax.rsqrt(ms + cfg.norm_eps).astype(x.dtype)
    return x * inv * p["scale"]


def rms_norm_headwise(x, scale, eps: float = 1e-6):
    """qk-norm: rmsnorm over the head_dim axis of (..., head_dim)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# Cotangent dtype guard
# ---------------------------------------------------------------------------
# f32-accumulating ops (norm sum-of-squares, attention score einsums with
# preferred_element_type=f32) make their *cotangents* f32; the f32-ness then
# propagates through every downstream backward op, doubling the bytes of all
# backward weight/activation all-gathers (measured on deepseek train_4k:
# the dominant collective cost).  Identity forward; backward casts the
# cotangent to the primal dtype.

import functools  # noqa: E402


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _grad_cast(x, dtype_str: str):
    return x


def _gdg_fwd(x, dtype_str):
    return x, None


def _gdg_bwd(dtype_str, _res, g):
    return (g.astype(dtype_str),)


_grad_cast.defvjp(_gdg_fwd, _gdg_bwd)


def grad_dtype_guard(x):
    return _grad_cast(x, str(x.dtype))
