"""Model stack: paper CNN + production transformer/SSM architectures."""
from .transformer import Transformer, init_params, count_params, active_params  # noqa: F401
from .cnn import CNN  # noqa: F401
