"""Model stack: paper CNN + production transformer/SSM architectures,
plus the federated model registry (cnn / mlp / transformer classifiers
with one shared init/apply contract)."""
from .transformer import (Transformer, TransformerClassifier,  # noqa: F401
                          active_params, count_params, init_params)
from .cnn import CNN  # noqa: F401
from .mlp import MLPClassifier  # noqa: F401
from .registry import ModelSpec, build_model, parse_model  # noqa: F401
