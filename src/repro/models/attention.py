"""Attention flavours: GQA (opt. sliding window, qk-norm, bias), MLA
(DeepSeek-V2 latent attention, absorbed decode path), cross-attention.

All masking is position-driven: query positions ``q_pos`` (B, T) and key
positions ``kv_pos`` (B, S) with -1 marking empty cache slots.  This makes
full, causal, sliding-window and ring-buffer cache attention one code path.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init, dtype_of, rms_norm_headwise
from .rope import apply_rope, mrope_cos_sin, rope_cos_sin
from .shardhooks import constrain  # noqa: F401  (used in both paths)

NEG_INF = -1e30
# Above this many query tokens, use the chunked online-softmax path so the
# (T, S) score matrix is never materialised in full.
CHUNKED_THRESHOLD = 1024
Q_CHUNK = 512
KV_CHUNK = 1024


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_attn(cfg, key):
    dt = dtype_of(cfg)
    D, H, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 10)
    if cfg.attn_type == "mla":
        nope = cfg.head_dim
        p = {
            "wq_a": dense_init(ks[0], D, cfg.q_lora_rank, dt),
            "q_norm": jnp.ones((cfg.q_lora_rank,), dt),
            "wq_b": dense_init(ks[1], cfg.q_lora_rank,
                               H * (nope + cfg.rope_head_dim), dt),
            "wkv_a": dense_init(ks[2], D, cfg.kv_lora_rank + cfg.rope_head_dim, dt),
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), dt),
            "wk_b": dense_init(ks[3], cfg.kv_lora_rank, H * nope, dt),
            "wv_b": dense_init(ks[4], cfg.kv_lora_rank, H * cfg.v_head, dt),
            "wo": dense_init(ks[5], H * cfg.v_head, D, dt),
        }
        return p
    p = {
        "wq": dense_init(ks[0], D, H * hd, dt),
        "wk": dense_init(ks[1], D, Hkv * hd, dt),
        "wv": dense_init(ks[2], D, Hkv * hd, dt),
        "wo": dense_init(ks[3], H * hd, D, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((Hkv * hd,), dt)
        p["bv"] = jnp.zeros((Hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((hd,), dt)
        p["k_scale"] = jnp.ones((hd,), dt)
    if cfg.cross_attention:
        p["xwq"] = dense_init(ks[4], D, H * hd, dt)
        p["xwk"] = dense_init(ks[5], D, Hkv * hd, dt)
        p["xwv"] = dense_init(ks[6], D, Hkv * hd, dt)
        p["xwo"] = dense_init(ks[7], H * hd, D, dt)
    return p


# ---------------------------------------------------------------------------
# Core masked attention (grouped-query, never repeats KV)
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, kv_pos, window, causal):
    """(B, T, S) additive bias from positions. Empty slots: kv_pos == -1."""
    valid = kv_pos[:, None, :] >= 0
    if causal:
        valid &= kv_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        valid &= (q_pos[:, :, None] - kv_pos[:, None, :]) < window
    return jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)


def _gqa_scores(q, k):
    """q: (B,T,Hkv,G,d)  k: (B,S,Hkv,d) -> (B,Hkv,G,T,S) fp32."""
    return jnp.einsum("bthgd,bshd->bhgts", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_out(p, v):
    """p: (B,Hkv,G,T,S)  v: (B,S,Hkv,d) -> (B,T,Hkv,G,d)."""
    return jnp.einsum("bhgts,bshd->bthgd", p.astype(v.dtype), v)


def masked_attention(q, k, v, q_pos, kv_pos, *, scale, window=None,
                     causal=True):
    """Grouped attention. q: (B,T,Hq,d), k/v: (B,S,Hkv,dv).

    Dense path for short T, chunked online-softmax path for long T.
    """
    B, T, Hq, d = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, T, Hkv, G, d)

    if T <= CHUNKED_THRESHOLD:
        bias = _mask_bias(q_pos, kv_pos, window, causal)  # (B,T,S)
        s = _gqa_scores(qg, k) * scale + bias[:, None, None]
        # decode with a sequence-sharded cache: keep the scores sharded on
        # the key axis (distributed softmax costs only tiny stat reduces,
        # vs GSPMD's default of all-gathering the multi-GB cache)
        s = constrain(s, "scores_seq")
        p = jax.nn.softmax(s, axis=-1)
        o = _gqa_out(p, v)
        return o.reshape(B, T, Hq, v.shape[-1])

    # ---- chunked online-softmax (flash-style, pure jnp + lax.scan) ----
    nq = T // Q_CHUNK
    assert T % Q_CHUNK == 0, f"T={T} not divisible by q-chunk {Q_CHUNK}"
    qc = qg.reshape(B, nq, Q_CHUNK, Hkv, G, d)
    qpc = q_pos.reshape(B, nq, Q_CHUNK)

    S = k.shape[1]
    if S % KV_CHUNK:  # pad keys; padded slots carry kv_pos = -1 (masked)
        pad = -(-S // KV_CHUNK) * KV_CHUNK - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        S += pad
    nk = S // KV_CHUNK
    kc = k.reshape(B, nk, KV_CHUNK, Hkv, d)
    vc = v.reshape(B, nk, KV_CHUNK, Hkv, v.shape[-1])
    kpc = kv_pos.reshape(B, nk, KV_CHUNK)

    def q_block(carry, inputs):
        qi, qp = inputs  # (B,Qc,Hkv,G,d), (B,Qc)

        # rematerialised: backward recomputes score blocks instead of
        # storing the full (T, S) score matrix across both scans
        @jax.checkpoint
        def kv_block(acc, kv_in):
            m, l, o = acc
            ki, vi, kp = kv_in
            bias = _mask_bias(qp, kp, window, causal)  # (B,Qc,Kc)
            s = _gqa_scores(qi, ki) * scale + bias[:, None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            o_new = o * alpha[..., None] + _gqa_out(p, vi).astype(jnp.float32) \
                .transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,Qc,dv)
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, Hkv, G, Q_CHUNK), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, Q_CHUNK), jnp.float32)
        o0 = jnp.zeros((B, Hkv, G, Q_CHUNK, v.shape[-1]), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            kv_block, (m0, l0, o0),
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
             kpc.transpose(1, 0, 2)))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        # cast before stacking: the scan ys otherwise accumulate in f32,
        # doubling the stacked output memory
        o = o.astype(q.dtype)
        # (B,Hkv,G,Qc,dv) -> (B,Qc,Hkv,G,dv)
        return carry, o.transpose(0, 3, 1, 2, 4)

    q_block = jax.checkpoint(q_block)
    _, oc = jax.lax.scan(
        q_block, 0,
        (qc.transpose(1, 0, 2, 3, 4, 5), qpc.transpose(1, 0, 2)))
    # oc: (nq, B, Qc, Hkv, G, dv)
    o = oc.transpose(1, 0, 2, 3, 4, 5).reshape(B, T, Hq, v.shape[-1])
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block forward
# ---------------------------------------------------------------------------

def _proj(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b
    return y


def gqa_attention(cfg, p, x, q_pos, kv_pos, cache=None, positions3=None):
    """x: (B,T,D). cache: None (train/prefill) or dict(k,v) ring/linear cache.

    Returns (out, new_cache). When cache is given, T==1 (decode) or T==S
    (prefill writing into the cache).
    """
    B, T, D = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = constrain(_proj(x, p["wq"], p.get("bq")).reshape(B, T, H, hd),
                  "heads")
    k = constrain(_proj(x, p["wk"], p.get("bk")).reshape(B, T, Hkv, hd),
                  "kv")
    v = constrain(_proj(x, p["wv"], p.get("bv")).reshape(B, T, Hkv, hd),
                  "kv")

    if cfg.qk_norm:
        q = rms_norm_headwise(q, p["q_scale"])
        k = rms_norm_headwise(k, p["k_scale"])

    if cfg.pos_emb == "rope":
        if cfg.mrope:
            assert positions3 is not None
            cos, sin = mrope_cos_sin(positions3, hd, cfg.rope_theta)
        else:
            cos, sin = rope_cos_sin(q_pos, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    scale = 1.0 / math.sqrt(hd)
    new_cache = None
    if cache is not None and T == 1:
        # ---- decode: scatter this token's k/v into its slot ----
        slots = _cache_slots(cfg, q_pos, cache["k"].shape[1])  # (B,1)
        if cfg.kv_quant:
            qk, ks = _quantize_kv(k)
            qv, vs = _quantize_kv(v)
            new_cache = {
                "k": _scatter_cache(cache["k"], qk, slots),
                "v": _scatter_cache(cache["v"], qv, slots),
                "k_scale": _scatter_cache(cache["k_scale"], ks, slots),
                "v_scale": _scatter_cache(cache["v_scale"], vs, slots),
            }
            ck = _dequantize_kv(new_cache["k"], new_cache["k_scale"],
                                k.dtype)
            cv = _dequantize_kv(new_cache["v"], new_cache["v_scale"],
                                v.dtype)
        else:
            ck = _scatter_cache(cache["k"], k, slots)
            cv = _scatter_cache(cache["v"], v, slots)
            new_cache = {"k": ck, "v": cv}
        o = masked_attention(q, ck, cv, q_pos, kv_pos, scale=scale,
                             window=cfg.sliding_window, causal=True)
    elif cache is not None:
        # ---- prefill: full attention, then build the cache from the tail
        o = masked_attention(q, k, v, q_pos, q_pos, scale=scale,
                             window=cfg.sliding_window, causal=True)
        Sc = cache["k"].shape[1]
        if cfg.kv_quant:
            qk, ks = _quantize_kv(k)
            qv, vs = _quantize_kv(v)
            new_cache = {"k": _tail_cache(qk, Sc), "v": _tail_cache(qv, Sc),
                         "k_scale": _tail_cache(ks, Sc),
                         "v_scale": _tail_cache(vs, Sc)}
        else:
            new_cache = {"k": _tail_cache(k, Sc), "v": _tail_cache(v, Sc)}
    else:
        o = masked_attention(q, k, v, q_pos, kv_pos, scale=scale,
                             window=cfg.sliding_window, causal=True)
    return o.reshape(B, T, H * hd) @ p["wo"], new_cache


def cross_attention(cfg, p, x, enc_kv):
    """Whisper cross-attention. enc_kv: dict(k,v): (B,Senc,Hkv,hd)."""
    B, T, D = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    q = (x @ p["xwq"]).reshape(B, T, H, hd)
    S = enc_kv["k"].shape[1]
    q_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    kv_pos = jnp.zeros((B, S), jnp.int32)  # all valid, non-causal
    o = masked_attention(q, enc_kv["k"], enc_kv["v"], q_pos, kv_pos,
                         scale=1.0 / math.sqrt(hd), causal=False)
    return o.reshape(B, T, H * hd) @ p["xwo"]


def encode_cross_kv(cfg, p, enc_out):
    B, S, _ = enc_out.shape
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    k = (enc_out @ p["xwk"]).reshape(B, S, Hkv, hd)
    v = (enc_out @ p["xwv"]).reshape(B, S, Hkv, hd)
    return {"k": k, "v": v}


def _cache_slots(cfg, q_pos, cache_len):
    if cfg.sliding_window is not None and cache_len <= cfg.sliding_window:
        return q_pos % cache_len  # ring buffer
    return q_pos


def _scatter_cache(cache, new, slots):
    """cache: (B,Smax,H,d); new: (B,T,H,d); slots: (B,T) int."""
    B, T = slots.shape
    if T == cache.shape[1] and T > 1:
        return new  # prefill covering whole cache
    b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, T))
    return cache.at[b_idx, slots].set(new.astype(cache.dtype))


def _quantize_kv(x):
    """x: (B,S,H,d) -> (int8 values, per-(pos,head) f32 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)  # (B,S,H)
    sc = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / sc[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, sc


def _dequantize_kv(q, sc, dtype):
    return (q.astype(jnp.float32) * sc[..., None]).astype(dtype)


def _tail_cache(k, Sc: int):
    """Build a (ring) cache holding the last ``Sc`` of ``k``: (B,S,H,d)."""
    S = k.shape[1]
    if Sc == S:
        return k
    if Sc > S:  # linear cache with free slots at the end
        return jnp.pad(k, ((0, 0), (0, Sc - S)) + ((0, 0),) * (k.ndim - 2))
    tail = k[:, S - Sc:]
    # position p lives at slot p % Sc; tail index i is position S-Sc+i
    return jnp.roll(tail, shift=(S - Sc) % Sc, axis=1)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_attention(cfg, p, x, q_pos, kv_pos, cache=None):
    """Multi-head latent attention.

    Train/prefill: materialise per-head K/V from the latent (standard path).
    Decode (cache): *absorbed* path — scores and values computed directly in
    the 512-d latent space; the cache stores only (c_kv, k_rope).
    """
    B, T, D = x.shape
    H = cfg.num_heads
    nope, rp, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head
    lora = cfg.kv_lora_rank

    cq = rms_norm_headwise(x @ p["wq_a"], p["q_norm"])
    q = (cq @ p["wq_b"]).reshape(B, T, H, nope + rp)
    q_nope, q_rope = q[..., :nope], q[..., nope:]

    ckv_full = x @ p["wkv_a"]  # (B,T,lora+rp)
    ckv = rms_norm_headwise(ckv_full[..., :lora], p["kv_norm"])
    k_rope = ckv_full[..., lora:][:, :, None, :]  # (B,T,1,rp)

    cos, sin = rope_cos_sin(q_pos, rp, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope, cos, sin)

    scale = 1.0 / math.sqrt(nope + rp)

    if cache is None or T > 1:
        # -------- standard (non-absorbed) path: train / prefill --------
        k_nope = (ckv @ p["wk_b"]).reshape(B, T, H, nope)
        v = constrain((ckv @ p["wv_b"]).reshape(B, T, H, dv), "heads")
        k = constrain(jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, T, H, rp))], axis=-1),
            "heads")
        qf = constrain(jnp.concatenate([q_nope, q_rope], axis=-1), "heads")
        o = masked_attention(qf, k, v, q_pos, q_pos, scale=scale,
                             window=cfg.sliding_window, causal=True)
        new_cache = None
        if cache is not None:  # prefill writes the latent cache
            Sc = cache["ckv"].shape[1]
            assert Sc >= T, "MLA prefill longer than the linear cache"
            new_cache = {
                "ckv": _tail_cache(ckv, Sc).astype(cache["ckv"].dtype),
                "kr": _tail_cache(k_rope[:, :, 0, :],
                                  Sc).astype(cache["kr"].dtype)}
        return o.reshape(B, T, H * dv) @ p["wo"], new_cache

    # -------- absorbed decode path (T == 1) --------
    slots = q_pos  # linear cache
    b_idx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, T))
    c_ckv = cache["ckv"].at[b_idx, slots].set(ckv.astype(cache["ckv"].dtype))
    c_kr = cache["kr"].at[b_idx, slots].set(
        k_rope[:, :, 0, :].astype(cache["kr"].dtype))
    new_cache = {"ckv": c_ckv, "kr": c_kr}

    wk_b = p["wk_b"].reshape(lora, H, nope)
    wv_b = p["wv_b"].reshape(lora, H, dv)
    # absorb W_uk into the query:  (B,T,H,lora)
    q_lat = jnp.einsum("bthn,lhn->bthl", q_nope, wk_b)
    s_lat = jnp.einsum("bthl,bsl->bhts", q_lat, c_ckv,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bthr,bsr->bhts", q_rope, c_kr,
                        preferred_element_type=jnp.float32)
    bias = _mask_bias(q_pos, kv_pos, cfg.sliding_window, True)
    s = (s_lat + s_rope) * scale + bias[:, None]
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhts,bsl->bthl", pr.astype(c_ckv.dtype), c_ckv)
    o = jnp.einsum("bthl,lhv->bthv", o_lat, wv_b)
    return o.reshape(B, T, H * dv) @ p["wo"], new_cache
