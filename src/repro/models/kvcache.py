"""Decode-time caches for every architecture family.

Cache layout (all per-layer leaves stacked on a leading layer axis so the
decode step can ``lax.scan`` over layers):

  dense / vlm : {"k","v": (L, B, Sc, Hkv, hd)}
  mla         : {"ckv": (L, B, Sc, lora), "kr": (L, B, Sc, rp)}
  ssm         : {"state": (L, B, H, N, P), "conv": (L, B, k-1, Cd)}
  hybrid      : {"mamba": {...(G, A, B, ...)}, "attn": {"k","v": (G, B, W, ...)}}
  audio       : dense cache + {"xk","xv": (L, B, Senc, Hkv, hd)} cross-attn

``Sc`` is ``min(seq_len, sliding_window)`` — SWA caches are ring buffers.
The scalar ``pos`` (next position to write) lives at the root; key positions
are *derived* from it (see ``kv_positions``), so empty/ring slots need no
stored metadata.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .mamba2 import conv_dim


def cache_len(cfg, seq_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def _layer_cache_shapes(cfg, batch: int, seq_len: int):
    """Per-layer cache leaf shapes (without the layer axis)."""
    dt = jnp.dtype(cfg.param_dtype)
    Sc = cache_len(cfg, seq_len)
    if cfg.attn_type == "mla":
        return {"ckv": ((batch, Sc, cfg.kv_lora_rank), dt),
                "kr": ((batch, Sc, cfg.rope_head_dim), dt)}
    if cfg.kv_quant:
        # int8 cache + per-(position, head) symmetric scales: halves the
        # decode memory roofline term (EXPERIMENTS.md §Perf H3 extension)
        kv = (batch, Sc, cfg.num_kv_heads, cfg.head_dim)
        sc = (batch, Sc, cfg.num_kv_heads)
        return {"k": (kv, jnp.int8), "v": (kv, jnp.int8),
                "k_scale": (sc, jnp.float32), "v_scale": (sc, jnp.float32)}
    return {"k": ((batch, Sc, cfg.num_kv_heads, cfg.head_dim), dt),
            "v": ((batch, Sc, cfg.num_kv_heads, cfg.head_dim), dt)}


def _mamba_cache_shapes(cfg, batch: int):
    dt = jnp.dtype(cfg.param_dtype)
    return {"state": ((batch, cfg.ssm_heads, cfg.ssm_state,
                       cfg.ssm_head_dim), jnp.float32),
            "conv": ((batch, cfg.ssm_conv - 1, conv_dim(cfg)), dt)}


def cache_shapes(cfg, batch: int, seq_len: int):
    """Full cache pytree of (shape, dtype) pairs."""
    L = cfg.num_layers
    out = {"pos": ((), jnp.int32)}
    if cfg.family in ("dense", "vlm", "moe"):
        out["layers"] = {k: ((L,) + s, d) for k, (s, d)
                         in _layer_cache_shapes(cfg, batch, seq_len).items()}
    elif cfg.family == "ssm":
        out["layers"] = {k: ((L,) + s, d) for k, (s, d)
                         in _mamba_cache_shapes(cfg, batch).items()}
    elif cfg.family == "hybrid":
        G = L // cfg.attn_every
        A = cfg.attn_every
        out["mamba"] = {k: ((G, A) + s, d) for k, (s, d)
                        in _mamba_cache_shapes(cfg, batch).items()}
        out["attn"] = {k: ((G,) + s, d) for k, (s, d)
                       in _layer_cache_shapes(cfg, batch, seq_len).items()}
    elif cfg.family == "audio":
        out["layers"] = {k: ((L,) + s, d) for k, (s, d)
                         in _layer_cache_shapes(cfg, batch, seq_len).items()}
        dt = jnp.dtype(cfg.param_dtype)
        xkv = (L, batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
        out["layers"]["xk"] = (xkv, dt)
        out["layers"]["xv"] = (xkv, dt)
    else:
        raise ValueError(f"no cache for family {cfg.family}")
    return out


def init_cache(cfg, batch: int, seq_len: int):
    shapes = cache_shapes(cfg, batch, seq_len)
    return jax.tree.map(lambda sd: jnp.zeros(sd[0], sd[1]), shapes,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        isinstance(x[0], tuple))


def cache_specs(cfg, batch: int, seq_len: int):
    shapes = cache_shapes(cfg, batch, seq_len)
    return jax.tree.map(lambda sd: jax.ShapeDtypeStruct(sd[0], sd[1]), shapes,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        isinstance(x[0], tuple))


def kv_positions(cfg, pos, Sc: int, batch: int):
    """Positions held by each cache slot given the write pointer ``pos``
    (position about to be written is ``pos``; slots with no data -> -1)."""
    slots = jnp.arange(Sc)
    # ring buffer iff the cache was capped at the sliding window
    ring = cfg.sliding_window is not None and Sc == cfg.sliding_window
    if ring:
        W = Sc
        # largest q <= pos with q % W == slot
        q = pos - ((pos - slots) % W)
        kv = jnp.where(q >= 0, q, -1)
    else:
        kv = jnp.where(slots <= pos, slots, -1)
    return jnp.broadcast_to(kv, (batch, Sc)).astype(jnp.int32)
