"""The paper's on-device model: 3-layer CNN (2 conv + 1 FC), ~12.5k weights.

Sec. IV: "Every device has a 3-layer convolutional neural network model
(2 convolutional layers, 1 fully-connected layer) having N_mod = 12,544."
Exact layer shapes are unpublished; our reconstruction
(conv 1->14 3x3, pool 2, conv 14->20 3x3, pool 2, fc 980->10) gives 12,490
parameters on the default 28x28x1 digits geometry — recorded in
configs/paper_cnn.py.  The conv stack and FC fan-in derive from
``input_shape``, so the same class serves any registered task shape
(e.g. the CIFAR-shaped 32x32x3 task) without touching the paper
defaults.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.paper_cnn import CONV_CHANNELS, IMAGE_SIZE, KERNEL, NUM_CLASSES, POOL


def _conv_init(key, k, cin, cout):
    scale = 1.0 / jnp.sqrt(k * k * cin)
    w = jax.random.normal(key, (k, k, cin, cout), jnp.float32) * scale
    return w


class CNN:
    """Functional CNN: params pytree + pure apply. Input: (B, *input_shape)."""

    def __init__(self, num_classes: int = NUM_CLASSES,
                 input_shape: tuple = (IMAGE_SIZE, IMAGE_SIZE, 1)):
        if len(input_shape) != 3:
            raise ValueError(
                f"CNN input_shape must be (H, W, C), got {input_shape}")
        self.num_classes = num_classes
        self.input_shape = tuple(int(s) for s in input_shape)
        h, w, _ = self.input_shape
        c1, c2 = CONV_CHANNELS
        # two VALID pool-2 stages: floor division per stage
        self.fc_in = (h // POOL // POOL) * (w // POOL // POOL) * c2
        if self.fc_in == 0:
            raise ValueError(
                f"input_shape {self.input_shape} too small for two "
                f"pool-{POOL} stages")

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        c1, c2 = CONV_CHANNELS
        cin = self.input_shape[2]
        return {
            "conv1": {"w": _conv_init(k1, KERNEL, cin, c1),
                      "b": jnp.zeros((c1,), jnp.float32)},
            "conv2": {"w": _conv_init(k2, KERNEL, c1, c2),
                      "b": jnp.zeros((c2,), jnp.float32)},
            "fc": {"w": jax.random.normal(k3, (self.fc_in, self.num_classes),
                                          jnp.float32) / jnp.sqrt(self.fc_in),
                   "b": jnp.zeros((self.num_classes,), jnp.float32)},
        }

    @staticmethod
    def _conv(x, p):
        y = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return y + p["b"]

    @staticmethod
    def _pool(x):
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, POOL, POOL, 1), (1, POOL, POOL, 1),
            "VALID")

    def apply(self, params, x):
        """x: (B, *input_shape) -> logits (B, num_classes)."""
        if tuple(x.shape[1:]) != self.input_shape:
            raise ValueError(
                f"CNN built for input shape {self.input_shape} but got a "
                f"batch of shape {tuple(x.shape[1:])}")
        h = jax.nn.relu(self._conv(x, params["conv1"]))
        h = self._pool(h)
        h = jax.nn.relu(self._conv(h, params["conv2"]))
        h = self._pool(h)
        h = h.reshape(h.shape[0], -1)
        return h @ params["fc"]["w"] + params["fc"]["b"]

    def num_params(self, params) -> int:
        return sum(p.size for p in jax.tree.leaves(params))
