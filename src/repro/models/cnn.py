"""The paper's on-device model: 3-layer CNN (2 conv + 1 FC), ~12.5k weights.

Sec. IV: "Every device has a 3-layer convolutional neural network model
(2 convolutional layers, 1 fully-connected layer) having N_mod = 12,544."
Exact layer shapes are unpublished; our reconstruction
(conv 1->14 3x3, pool 2, conv 14->20 3x3, pool 2, fc 980->10) gives 12,490
parameters — recorded in configs/paper_cnn.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.paper_cnn import CONV_CHANNELS, IMAGE_SIZE, KERNEL, NUM_CLASSES, POOL


def _conv_init(key, k, cin, cout):
    scale = 1.0 / jnp.sqrt(k * k * cin)
    w = jax.random.normal(key, (k, k, cin, cout), jnp.float32) * scale
    return w


class CNN:
    """Functional CNN: params pytree + pure apply. Input: (B, 28, 28, 1)."""

    def __init__(self, num_classes: int = NUM_CLASSES):
        self.num_classes = num_classes
        c1, c2 = CONV_CHANNELS
        side = IMAGE_SIZE // POOL // POOL
        self.fc_in = side * side * c2

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        c1, c2 = CONV_CHANNELS
        return {
            "conv1": {"w": _conv_init(k1, KERNEL, 1, c1),
                      "b": jnp.zeros((c1,), jnp.float32)},
            "conv2": {"w": _conv_init(k2, KERNEL, c1, c2),
                      "b": jnp.zeros((c2,), jnp.float32)},
            "fc": {"w": jax.random.normal(k3, (self.fc_in, self.num_classes),
                                          jnp.float32) / jnp.sqrt(self.fc_in),
                   "b": jnp.zeros((self.num_classes,), jnp.float32)},
        }

    @staticmethod
    def _conv(x, p):
        y = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return y + p["b"]

    @staticmethod
    def _pool(x):
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, POOL, POOL, 1), (1, POOL, POOL, 1),
            "VALID")

    def apply(self, params, x):
        """x: (B, 28, 28, 1) -> logits (B, num_classes)."""
        h = jax.nn.relu(self._conv(x, params["conv1"]))
        h = self._pool(h)
        h = jax.nn.relu(self._conv(h, params["conv2"]))
        h = self._pool(h)
        h = h.reshape(h.shape[0], -1)
        return h @ params["fc"]["w"] + params["fc"]["b"]

    def num_params(self, params) -> int:
        return sum(p.size for p in jax.tree.leaves(params))
