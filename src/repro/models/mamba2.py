"""Mamba2 mixer: SSD (state-space duality) with chunked scan.

The chunked SSD here is also the mathematical oracle for the Pallas
``ssd_scan`` kernel (kernels/ref.py re-exports ``ssd_reference``).

Semantics (per head h, state N, head-dim P):
    h_t = exp(A_h * dt_t) h_{t-1} + dt_t * B_t x_t^T
    y_t = C_t . h_t + D_h x_t
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init, dtype_of
from .shardhooks import constrain


def conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def init_mamba(cfg, key):
    dt = dtype_of(cfg)
    D, di, H = cfg.d_model, cfg.d_inner, cfg.ssm_heads
    Cd = conv_dim(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], D, 2 * di + 2 * cfg.ssm_ngroups *
                              cfg.ssm_state + H, dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, Cd), jnp.float32)
                   / math.sqrt(cfg.ssm_conv)).astype(dt),
        "conv_b": jnp.zeros((Cd,), dt),
        "A_log": jnp.zeros((H,), jnp.float32),   # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.ones((di,), dt),
        "out_proj": dense_init(ks[2], di, D, dt),
    }


def _causal_conv(xBC, w, b):
    """Depthwise causal conv. xBC: (B,S,Cd); w: (k,Cd)."""
    k = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    S = xBC.shape[1]
    y = sum(pad[:, i:i + S, :] * w[i] for i in range(k))
    return y + b


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """Chunked SSD. x: (B,S,H,P) fp32, dt: (B,S,H), A: (H,),
    Bm/Cm: (B,S,G,N). Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    B_, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)
    dA = dt * A  # (B,S,H), <= 0
    xdt = x * dt[..., None]

    nc = S // chunk
    assert S % chunk == 0, f"S={S} not divisible by chunk {chunk}"
    L = chunk
    rs = lambda t: t.reshape((B_, nc, L) + t.shape[2:])
    xc, dAc, Bc, Cc = rs(xdt), rs(dA), rs(Bh), rs(Ch)

    seg = jnp.cumsum(dAc, axis=2)  # (B,nc,L,H) inclusive
    # ---- intra-chunk (attention-like) ----
    decay = seg[:, :, :, None, :] - seg[:, :, None, :, :]  # (B,nc,L,L,H) l,m
    causal = jnp.tril(jnp.ones((L, L), bool))
    att = jnp.exp(jnp.where(causal[None, None, :, :, None], decay, -jnp.inf))
    CB = jnp.einsum("bclhn,bcmhn->bclmh", Cc, Bc)
    y_intra = jnp.einsum("bclmh,bclmh,bcmhp->bclhp", CB, att, xc)

    # ---- per-chunk end states ----
    decay_last = jnp.exp(seg[:, :, -1:, :] - seg)  # (B,nc,L,H)
    states = jnp.einsum("bclh,bclhn,bclhp->bchnp", decay_last, Bc, xc)

    # ---- inter-chunk recurrence over nc ----
    chunk_decay = jnp.exp(seg[:, :, -1, :])  # (B,nc,H)

    def step(s, inp):
        cd, st = inp  # (B,H), (B,H,N,P)
        s_next = cd[..., None, None] * s + st
        return s_next, s  # emit the state *entering* this chunk

    s0 = initial_state if initial_state is not None else \
        jnp.zeros((B_, H, N, P), x.dtype)
    final, prev = jax.lax.scan(
        step, s0, (chunk_decay.transpose(1, 0, 2),
                   states.transpose(1, 0, 2, 3, 4)))
    prev = prev.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P)

    y_inter = jnp.einsum("bclh,bclhn,bchnp->bclhp",
                         jnp.exp(seg), Cc, prev)
    y = (y_intra + y_inter).reshape(B_, S, H, P)
    return y, final


def mamba2_forward(cfg, p, x, cache=None):
    """x: (B,S,D). cache (decode): {"state": (B,H,N,P), "conv": (B,k-1,Cd)}.
    Returns (out, new_cache)."""
    B_, S, D = x.shape
    di, H, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    GN = G * N

    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * GN]
    dt_raw = zxbcdt[..., -H:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if cache is None or S > 1:
        conv_in = xBC
        xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
        xs = constrain(
            xBC[..., :di].astype(jnp.float32).reshape(B_, S, H, P),
            "ssm_inner")
        Bm = xBC[..., di:di + GN].astype(jnp.float32).reshape(B_, S, G, N)
        Cm = xBC[..., di + GN:].astype(jnp.float32).reshape(B_, S, G, N)
        chunk = min(cfg.ssm_chunk, S)
        if S % chunk:  # pad with dt=0 steps: state passes through unchanged
            pad = -(-S // chunk) * chunk - S
            zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                                     [(0, 0)] * (t.ndim - 2))
            ys, final = ssd_chunked(zpad(xs), zpad(dt), A, zpad(Bm),
                                    zpad(Cm), chunk)
            y = ys[:, :S]
        else:
            y, final = ssd_chunked(xs, dt, A, Bm, Cm, chunk)
        new_cache = None
        if cache is not None:  # prefill: hand the state to decode
            k = cfg.ssm_conv
            new_cache = {
                "state": final.astype(cache["state"].dtype),
                "conv": conv_in[:, S - (k - 1):, :].astype(
                    cache["conv"].dtype),
            }
    else:
        # ---- single-token decode ----
        k = cfg.ssm_conv
        window = jnp.concatenate([cache["conv"], xBC], axis=1)  # (B,k,Cd)
        conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
        xBC1 = jax.nn.silu(conv_out)[:, None, :]  # (B,1,Cd)
        xs = xBC1[..., :di].astype(jnp.float32).reshape(B_, 1, H, P)
        Bm = xBC1[..., di:di + GN].astype(jnp.float32).reshape(B_, 1, G, N)
        Cm = xBC1[..., di + GN:].astype(jnp.float32).reshape(B_, 1, G, N)
        rep = H // G
        Bh = jnp.repeat(Bm[:, 0], rep, axis=1)  # (B,H,N)
        Ch = jnp.repeat(Cm[:, 0], rep, axis=1)
        a = jnp.exp(dt[:, 0] * A)  # (B,H)
        xdt = xs[:, 0] * dt[:, 0, :, None]  # (B,H,P)
        state = cache["state"].astype(jnp.float32)
        state = a[..., None, None] * state + \
            jnp.einsum("bhn,bhp->bhnp", Bh, xdt)
        y = jnp.einsum("bhn,bhnp->bhp", Ch, state)[:, None]  # (B,1,H,P)
        final = state
        new_cache = {"state": state.astype(cache["state"].dtype),
                     "conv": window[:, 1:].astype(cache["conv"].dtype)}

    y = y + p["D"][:, None] * (xs if cache is None else xs)
    y = y.reshape(B_, S, di)

    # gated RMSNorm
    g = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(ms + cfg.norm_eps) * p["norm"].astype(jnp.float32)
    out = g.astype(x.dtype) @ p["out_proj"]
    return out, new_cache
