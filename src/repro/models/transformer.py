"""Architecture assembly: init, train/prefill forward, single-token decode.

One ``Transformer`` facade covers all six assigned families (dense, moe,
ssm, hybrid, vlm, audio).  Layers are **scanned** (stacked params, leading
layer axis) with rematerialisation, so HLO size and compile time are
depth-independent and activation memory is O(1) in depth.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import kvcache
from .attention import (cross_attention, encode_cross_kv, gqa_attention,
                        mla_attention)
from .layers import (apply_norm, dense_init, dtype_of, embed_init,
                     grad_dtype_guard, init_norm)
from .mamba2 import init_mamba, mamba2_forward
from .mlp import init_mlp, mlp
from .moe import init_moe, moe_ffn
from .shardhooks import constrain

# Minimal-memory remat: each scanned layer saves only its input; the whole
# layer recomputes in backward.  (dots_with_no_batch_dims_saveable was
# measured to save ~10 activation tensors per layer at 1M-token batches —
# see EXPERIMENTS.md §Perf iteration log.)
REMAT_POLICY = jax.checkpoint_policies.nothing_saveable


# optimization_barrier has neither a JVP nor a batching rule in this jax
# version, which breaks jax.grad / jax.vmap through the scanned blocks.
# The barrier only needs to pin the *primal* graph, so register identity
# rules for both transforms (guarded: future jax may ship its own, or may
# move the private primitive — in which case it likely has the rules too).
try:
    from jax._src.lax import lax as _lax_internal  # noqa: E402
    from jax.interpreters import ad as _ad, batching as _batching  # noqa: E402

    _obar_p = _lax_internal.optimization_barrier_p
    if _obar_p not in _batching.primitive_batchers:
        _batching.primitive_batchers[_obar_p] = (
            lambda args, dims: (_obar_p.bind(*args), dims))
    if _obar_p not in _ad.primitive_jvps:
        _ad.primitive_jvps[_obar_p] = (
            lambda primals, tangents: (_obar_p.bind(*primals),
                                       list(tangents)))
except (ImportError, AttributeError):
    pass


def _opt_barrier(x):
    return jax.lax.optimization_barrier(x)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(cfg, key):
    """One transformer block (dense / moe / audio flavours)."""
    ks = jax.random.split(key, 4)
    p = {"ln1": init_norm(cfg, cfg.d_model), "ln2": init_norm(cfg, cfg.d_model)}
    p["attn"] = attn_mod.init_attn(cfg, ks[0])
    if cfg.is_moe:
        p["moe"] = init_moe(cfg, ks[1])
    else:
        p["mlp"] = init_mlp(cfg, ks[1])
    if cfg.cross_attention:
        p["ln_x"] = init_norm(cfg, cfg.d_model)
    return p


def _stack(init_fn, cfg, key, n):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(cfg, k))(keys)


def init_params(cfg, key):
    ks = jax.random.split(key, 8)
    dt = dtype_of(cfg)
    p = {"final_norm": init_norm(cfg, cfg.d_model)}
    if not cfg.embed_input:
        p["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt)
    if not cfg.tie_embeddings or cfg.embed_input:
        p["unembed"] = embed_init(ks[1], cfg.vocab_size, cfg.d_model, dt).T
    if cfg.pos_emb == "learned":
        p["pos_embed"] = embed_init(ks[2], cfg.max_position, cfg.d_model, dt)

    if cfg.family in ("dense", "vlm", "moe", "audio"):
        p["blocks"] = _stack(_init_block, cfg, ks[3], cfg.num_layers)
    elif cfg.family == "ssm":
        p["blocks"] = _stack(
            lambda c, k: {"ln": init_norm(c, c.d_model),
                          "mamba": init_mamba(c, k)},
            cfg, ks[3], cfg.num_layers)
    elif cfg.family == "hybrid":
        G = cfg.num_layers // cfg.attn_every
        A = cfg.attn_every
        flat = _stack(
            lambda c, k: {"ln": init_norm(c, c.d_model),
                          "mamba": init_mamba(c, k)},
            cfg, ks[3], G * A)
        p["blocks"] = jax.tree.map(
            lambda x: x.reshape((G, A) + x.shape[1:]), flat)
        p["shared_attn"] = _init_block(cfg, ks[4])  # one shared block
    else:
        raise ValueError(cfg.family)
    return p


def unembed_matrix(cfg, params):
    if cfg.tie_embeddings and not cfg.embed_input:
        return params["embed"].T
    return params["unembed"]


# ---------------------------------------------------------------------------
# Blocks (functional)
# ---------------------------------------------------------------------------

def _attn_block(cfg, p, x, q_pos, kv_pos, cache, positions3, enc_out,
                enc_kv_cache):
    aux = jnp.zeros((), jnp.float32)
    # barrier: stops XLA hoisting a whole-stack f32 convert of the
    # remat-saved layer inputs out of the backward scan (measured 75 GiB
    # on deepseek train_4k; EXPERIMENTS.md §Perf)
    x = _opt_barrier(x)
    x = grad_dtype_guard(x)  # keep the residual cotangent in bf16
    x = constrain(x, "resid")
    h = apply_norm(cfg, p["ln1"], x)
    if cfg.attn_type == "mla":
        a, new_cache = mla_attention(cfg, p["attn"], h, q_pos, kv_pos, cache)
    else:
        a, new_cache = gqa_attention(cfg, p["attn"], h, q_pos, kv_pos, cache,
                                     positions3)
    x = x + a
    if cfg.cross_attention:
        h = apply_norm(cfg, p["ln_x"], x)
        if enc_kv_cache is not None:
            ekv = enc_kv_cache
        else:
            ekv = encode_cross_kv(cfg, p["attn"], enc_out)
        x = x + cross_attention(cfg, p["attn"], h, ekv)
        if new_cache is not None:
            new_cache = dict(new_cache, xk=ekv["k"], xv=ekv["v"])
    h = apply_norm(cfg, p["ln2"], x)
    if cfg.is_moe:
        y, aux = moe_ffn(cfg, p["moe"], h, constrain=_MOE_CONSTRAIN[0])
    else:
        y = mlp(cfg, p["mlp"], h)
    return x + y, aux, new_cache


def _mamba_block(cfg, p, x, cache):
    x = _opt_barrier(x)
    x = grad_dtype_guard(x)
    x = constrain(x, "resid")
    h = apply_norm(cfg, p["ln"], x)
    y, new_cache = mamba2_forward(cfg, p["mamba"], h, cache)
    return x + y, new_cache


# Hook for launch.sharding to constrain MoE dispatch tensors (set at trace
# time; single-element list so tests can leave it as identity).
_MOE_CONSTRAIN = [None]


def set_moe_constraint(fn):
    _MOE_CONSTRAIN[0] = fn


# ---------------------------------------------------------------------------
# Forward (train / prefill / decode)
# ---------------------------------------------------------------------------

def forward(cfg, params, batch, cache=None, *, remat=True,
            return_hidden=False):
    """Returns (logits, aux_loss, new_cache) — or (hidden, aux, cache)
    when ``return_hidden`` (the chunked loss computes logits itself so the
    full (B,S,V) tensor is never materialised).

    batch keys: "tokens" (B,T) or "embeds" (B,T,D); optional "enc_out"
    (B,Senc,D) for audio.  With ``cache``: decode (T==1) or cache-building
    prefill (T==seq).
    """
    if "embeds" in batch:
        x = batch["embeds"].astype(dtype_of(cfg))
        B, T = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, T = tokens.shape
        x = params["embed"][tokens]

    if cache is not None:
        pos0 = cache["pos"]
        Sc = _cache_slot_len(cfg, cache)
    else:
        pos0 = jnp.zeros((), jnp.int32)
        Sc = T

    q_pos = jnp.broadcast_to(pos0 + jnp.arange(T), (B, T)).astype(jnp.int32)
    kv_pos = None
    if cfg.family != "ssm":
        if cache is not None and T == 1:
            kv_pos = kvcache.kv_positions(cfg, pos0, Sc, B)
        else:
            kv_pos = q_pos  # train / prefill: attention over the live keys
    positions3 = jnp.stack([q_pos] * 3, axis=-1) if cfg.mrope else None

    if cfg.pos_emb == "learned":
        x = x + params["pos_embed"][q_pos[0]][None]

    enc_out = batch.get("enc_out")
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "vlm", "moe", "audio"):
        x, aux_total, new_layer_cache = _scan_attn_blocks(
            cfg, params["blocks"], x, q_pos, kv_pos, cache, positions3,
            enc_out, remat)
    elif cfg.family == "ssm":
        x, new_layer_cache = _scan_mamba_blocks(cfg, params["blocks"], x,
                                                cache, remat)
    elif cfg.family == "hybrid":
        x, new_layer_cache = _scan_hybrid(cfg, params, x, q_pos, kv_pos,
                                          cache, remat)
    else:
        raise ValueError(cfg.family)

    x = apply_norm(cfg, params["final_norm"], constrain(x, "resid"))

    new_cache = None
    if cache is not None:
        new_cache = dict(new_layer_cache)
        new_cache["pos"] = pos0 + T
    if return_hidden:
        return x, aux_total, new_cache
    logits = constrain(x @ unembed_matrix(cfg, params), "logits")
    return logits, aux_total, new_cache


def _cache_slot_len(cfg, cache):
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cache["attn"]["k"].shape[2]
    return cache["layers"]["k" if cfg.attn_type != "mla" else "ckv"].shape[2]


def _maybe_remat(fn, remat):
    return jax.checkpoint(fn, policy=REMAT_POLICY) if remat else fn


def _scan_attn_blocks(cfg, blocks, x, q_pos, kv_pos, cache, positions3,
                      enc_out, remat):
    has_cache = cache is not None
    decode = has_cache and x.shape[1] == 1

    def body(carry, inp):
        x, aux = carry
        if has_cache:
            lp, lc = inp
            enc_kv = {"k": lc["xk"], "v": lc["xv"]} if (
                cfg.cross_attention and decode and enc_out is None) else None
            layer_cache = {k: v for k, v in lc.items()
                           if k not in ("xk", "xv")}
        else:
            lp, layer_cache, enc_kv = inp, None, None
        # stop XLA hoisting a whole-stack dtype convert of the scanned
        # weights out of the loop (CPU lowering converts bf16 operands)
        lp = _opt_barrier(lp)
        y, aux_l, new_lc = _attn_block(cfg, lp, x, q_pos, kv_pos, layer_cache,
                                       positions3, enc_out, enc_kv)
        if has_cache and cfg.cross_attention and "xk" not in new_lc:
            new_lc = dict(new_lc, xk=lc["xk"], xv=lc["xv"])
        return (y, aux + aux_l), new_lc

    body = _maybe_remat(body, remat and not decode)
    xs = (blocks, cache["layers"]) if has_cache else blocks
    (x, aux), new_cache_layers = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs)
    out_cache = {"layers": new_cache_layers} if has_cache else None
    return x, aux, out_cache


def _scan_mamba_blocks(cfg, blocks, x, cache, remat):
    has_cache = cache is not None

    def body(x, inp):
        lp, lc = inp if has_cache else (inp, None)
        y, new_lc = _mamba_block(cfg, lp, x, lc)
        return y, new_lc

    body = _maybe_remat(body, remat and not has_cache)
    xs = (blocks, cache["layers"]) if has_cache else blocks
    x, new_layers = jax.lax.scan(body, x, xs)
    return x, ({"layers": new_layers} if has_cache else None)


def _scan_hybrid(cfg, params, x, q_pos, kv_pos, cache, remat):
    """Zamba2: G super-blocks of (attn_every mamba layers + shared attn)."""
    has_cache = cache is not None
    shared = params["shared_attn"]
    decode = has_cache and x.shape[1] == 1

    def inner(x, inp):
        lp, lc = inp if has_cache else (inp, None)
        y, new_lc = _mamba_block(cfg, lp, x, lc)
        return y, new_lc

    def body(x, inp):
        if has_cache:
            mp, mc, ac = inp
            x, new_mc = jax.lax.scan(inner, x, (mp, mc))
        else:
            mp, ac = inp, None
            x, new_mc = jax.lax.scan(inner, x, mp)
        # shared attention block (same weights every super-block)
        y, _, new_ac = _attn_block(cfg, shared, x, q_pos, kv_pos,
                                   ac if has_cache else None, None, None,
                                   None)
        if has_cache:
            return y, (new_mc, new_ac)
        return y, None

    body = _maybe_remat(body, remat and not decode)
    if has_cache:
        xs = (params["blocks"], cache["mamba"], cache["attn"])
        x, (new_m, new_a) = jax.lax.scan(body, x, xs)
        return x, {"mamba": new_m, "attn": new_a}
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return x, None


# ---------------------------------------------------------------------------
# Facade + param accounting
# ---------------------------------------------------------------------------

class Transformer:
    """Thin facade bundling config + pure functions."""

    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, key):
        return init_params(self.cfg, key)

    def apply(self, params, batch, cache=None, remat=True):
        return forward(self.cfg, params, batch, cache, remat=remat)

    def init_cache(self, batch_size, seq_len):
        return kvcache.init_cache(self.cfg, batch_size, seq_len)


class TransformerClassifier:
    """Tiny dense transformer as a federated client model: flatten the
    input, cut it into ``seq_len`` patch tokens, project to d_model, run
    the scanned dense stack, mean-pool position logits.

    Same .init/.apply contract as :class:`repro.models.cnn.CNN` (float32
    params, logits (B, num_classes)), so FD-family cohorts can mix it
    with the conv/MLP clients.  Built on the same ``init_params`` /
    ``forward`` stack the serving configs use (``embed_input`` front
    door, learned positions, GELU MLP)."""

    def __init__(self, num_classes: int, input_shape: tuple,
                 d_model: int = 32, num_layers: int = 2, num_heads: int = 2,
                 head_dim: int = 16, d_ff: int = 64, seq_len: int = 16):
        from ..configs import ArchConfig  # local: configs never imports models
        self.num_classes = num_classes
        self.input_shape = tuple(int(s) for s in input_shape)
        total = 1
        for s in self.input_shape:
            total *= s
        if total % seq_len:
            raise ValueError(
                f"input shape {self.input_shape} ({total} features) does "
                f"not split into seq_len={seq_len} patch tokens")
        self.seq_len = seq_len
        self.patch_dim = total // seq_len
        self.cfg = ArchConfig(
            name="fed_transformer", family="dense",
            source="registry classifier (this repo)",
            num_layers=num_layers, d_model=d_model, num_heads=num_heads,
            num_kv_heads=num_heads, d_ff=d_ff, vocab_size=num_classes,
            head_dim=head_dim, attn_type="gqa", pos_emb="learned",
            max_position=seq_len, embed_input=True, mlp_act="gelu",
            param_dtype="float32")

    def init(self, key):
        kp, kt = jax.random.split(key)
        patch = {"w": dense_init(kp, self.patch_dim, self.cfg.d_model,
                                 jnp.float32),
                 "b": jnp.zeros((self.cfg.d_model,), jnp.float32)}
        return {"patch": patch, "tf": init_params(self.cfg, kt)}

    def apply(self, params, x):
        """x: (B, *input_shape) -> logits (B, num_classes)."""
        if tuple(x.shape[1:]) != self.input_shape:
            raise ValueError(
                f"TransformerClassifier built for input shape "
                f"{self.input_shape} but got a batch of shape "
                f"{tuple(x.shape[1:])}")
        toks = x.reshape(x.shape[0], self.seq_len, self.patch_dim)
        h = toks @ params["patch"]["w"] + params["patch"]["b"]
        logits, _, _ = forward(self.cfg, params["tf"], {"embeds": h},
                               remat=False)
        return logits.mean(axis=1)

    def num_params(self, params) -> int:
        return sum(p.size for p in jax.tree.leaves(params))


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def active_params(cfg, params) -> int:
    """Active parameters per token (MoE: top_k of routed experts)."""
    total = count_params(params)
    if not cfg.is_moe:
        return total

    def routed_size(p):
        return sum(p["blocks"]["moe"][w].size for w in ("w1", "w2", "w3"))

    routed = routed_size(params)
    active_routed = routed * cfg.top_k / cfg.num_experts
    return int(total - routed + active_routed)
