"""Activation-sharding hook.

The launch layer installs a constraint function (built from the mesh +
arch policy); the model calls ``constrain(x, kind)`` at the points where
GSPMD propagation tends to lose the batch sharding (scan boundaries,
attention chunking, MoE dispatch).  On CPU tests nothing is installed and
these are identity.

kinds: resid (B,S,D) | heads (B,S,H,d) | kv (B,S,Hkv,d) | logits (B,S,V)
       ssm_inner (B,S,H,P) | ssm_state (B,H,N,P) | moe_dispatch (G,E,C,D)
"""
from __future__ import annotations

_HOOK = [None]


def set_activation_sharding(fn) -> None:
    _HOOK[0] = fn


def constrain(x, kind: str):
    if _HOOK[0] is None:
        return x
    return _HOOK[0](x, kind)
