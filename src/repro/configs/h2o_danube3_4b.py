"""H2O-Danube3 4B — llama/mistral mix with sliding-window attention. [arXiv:2401.16818]"""
from . import ArchConfig, register

CONFIG = register(ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    source="arXiv:2401.16818",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,        # 3840 / 32
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,  # SWA => long_500k admissible with bounded cache
))
