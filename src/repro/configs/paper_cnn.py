"""The paper's own model: 3-layer CNN (2 conv + 1 FC), ~12.5k weights,
10-class MNIST-style 28x28 inputs.  N_mod in the paper is 12,544; the exact
layer shapes are unpublished — our reconstruction (conv 1->14, conv 14->20,
fc 980->10) lands at 12,490 weights, recorded here.
"""
from . import ArchConfig, register

CONFIG = register(ArchConfig(
    name="paper-cnn",
    family="cnn",
    source="Mix2FLD (this paper), Sec. IV",
    num_layers=3,
    d_model=28,          # image side
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=10,       # N_L = 10 labels
    attn_type="none",
    fd_buckets=10,       # exact per-label output vectors (no bucketing)
    param_dtype="float32",
))

# CNN-specific hyperparameters (used by repro.models.cnn)
CONV_CHANNELS = (14, 20)
KERNEL = 3
POOL = 2
IMAGE_SIZE = 28
NUM_CLASSES = 10
