"""Qwen2-VL 72B — VLM backbone, M-RoPE, GQA(64/8). Vision tower is a stub:
``input_specs`` supplies precomputed patch embeddings. [arXiv:2409.12191]"""
from . import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    mrope=True,
    qkv_bias=True,
    embed_input=True,    # patch/token embeddings provided by the stub frontend
    rope_theta=1e6,
    grad_accum=4,   # 64-seq microbatches at train_4k: fits 16 GB/chip HBM
))
