"""Mamba2 370M — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from . import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-370m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,              # no MLP: mamba2 blocks are mixer-only
    vocab_size=50280,
    attn_type="none",
    ssm_state=128,
    ssm_head_dim=64,     # d_inner 2048 -> 32 ssm heads
    ssm_expand=2,
))
