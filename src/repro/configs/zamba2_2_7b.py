"""Zamba2 2.7B — hybrid Mamba2 backbone + shared attention block. [arXiv:2411.15242]"""
from . import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,         # 2560 / 32
    d_ff=10240,          # shared block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,     # d_inner 5120 -> 80 ssm heads
    ssm_expand=2,
    attn_every=6,        # shared attention block applied every 6 mamba layers
    sliding_window=4096, # windowed shared attention => long_500k admissible
    grad_accum=2,        # SSD decay tensors at train_4k: fits 16 GB/chip
))
