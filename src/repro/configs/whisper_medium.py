"""Whisper-medium — encoder-decoder; conv/mel frontend is a stub supplying
encoder-output frame embeddings. We model the decoder transformer (self-attn +
cross-attn) with learned positions, LayerNorm and GELU. [arXiv:2212.04356]"""
from . import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    cross_attention=True,
    encoder_seq=1500,
    embed_input=False,   # decoder consumes tokens; encoder output is the stub
    pos_emb="learned",
    mlp_act="gelu",
    norm_type="layernorm",
    max_position=1 << 20,  # shape-only exercise beyond the real 448 cap
))
