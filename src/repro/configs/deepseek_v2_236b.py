"""DeepSeek-V2 236B — MLA + 160-expert MoE (2 shared, top-6). [arXiv:2405.04434]"""
from . import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,   # MLA: logical heads; cache is the 512-d latent
    head_dim=128,
    d_ff=1536,          # routed expert intermediate (assignment sheet)
    vocab_size=102400,
    attn_type="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    rope_theta=1e4,
    grad_accum=4,   # 32-seq microbatches at train_4k: fits 16 GB/chip HBM
))
