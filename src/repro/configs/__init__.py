"""Architecture config system.

One ``ArchConfig`` per assigned architecture (plus the paper's own CNN).
Every field needed by the model stack, the sharding policy, and the
dry-run input specs lives here, so ``--arch <id>`` fully determines the
program that gets lowered.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

# ---------------------------------------------------------------------------
# Input shapes (assigned; fixed across architectures)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ArchConfig:
    """Complete architecture description.

    ``d_ff`` follows the assignment sheet: for MoE archs it is the routed
    expert intermediate size (also exposed as ``moe_d_ff``).
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | cnn
    source: str  # citation from the assignment sheet
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- attention flavour ---------------------------------------------
    attn_type: str = "gqa"  # gqa | mla | none
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None  # None = full attention
    rope_theta: float = 1e4
    mrope: bool = False  # qwen2-vl multimodal rope (3 interleaved sections)
    pos_emb: str = "rope"  # rope | learned (whisper)
    max_position: int = 1 << 20

    # --- MLA (deepseek-v2) ----------------------------------------------
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: int = 0  # defaults to head_dim

    # --- MoE --------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 512  # tokens per dispatch group (GShard-style)
    router_aux_weight: float = 0.01

    # --- SSM (mamba2 / zamba2) -------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256

    # --- hybrid (zamba2) ---------------------------------------------------
    attn_every: int = 0  # apply the shared attention block every k layers

    # --- encoder-decoder (whisper) ----------------------------------------
    cross_attention: bool = False
    encoder_seq: int = 1500

    # --- frontend stub (vlm / audio) ---------------------------------------
    embed_input: bool = False  # inputs are precomputed embeddings

    # --- misc ---------------------------------------------------------------
    mlp_act: str = "silu"  # silu (SwiGLU) | gelu (plain MLP, whisper)
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- Mix2FLD / FD adaptation -------------------------------------------
    fd_buckets: int = 256  # vocab hash-buckets for per-label output vectors
    kd_beta: float = 0.01  # paper's beta

    # --- numerics / training -------------------------------------------------
    param_dtype: str = "bfloat16"
    kv_quant: bool = False  # int8 KV cache (+per-position/head scales)
    learning_rate: float = 0.01  # paper's eta
    grad_accum: int = 1          # microbatches per train step

    # ------------------------------------------------------------------
    @property
    def v_head(self) -> int:
        return self.v_head_dim or self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def subquadratic(self) -> bool:
        """True if long_500k decode is admissible (bounded per-token cost)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def supports_shape(self, shape_name: str) -> bool:
        shp = INPUT_SHAPES[shape_name]
        if shp.name == "long_500k" and not self.subquadratic:
            return False  # dense full-attention: documented skip
        return True

    # ------------------------------------------------------------------
    def smoke(self) -> "ArchConfig":
        """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        head_dim = 32
        num_heads = max(2, min(4, self.num_heads))
        num_kv = max(1, min(num_heads, self.num_kv_heads, 2))
        kw = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 4 * d_model) or self.d_ff,
            vocab_size=min(self.vocab_size, 512),
            max_position=4096,
            param_dtype="float32",
            fd_buckets=64,
            moe_group_size=64,
        )
        if self.is_moe:
            kw.update(
                num_experts=4,
                top_k=min(2, self.top_k),
                num_shared_experts=min(1, self.num_shared_experts),
                moe_d_ff=2 * d_model,
                d_ff=2 * d_model,
                # dropless in smoke configs: capacity >= group size makes
                # full-vs-incremental parity exact (capacity drops are
                # grouping-dependent by construction)
                capacity_factor=float(4 // max(1, min(2, self.top_k))),
            )
        if self.attn_type == "mla":
            kw.update(kv_lora_rank=64, q_lora_rank=96, rope_head_dim=16,
                      v_head_dim=32)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
        if self.attn_every:
            kw.update(attn_every=2)
        if self.sliding_window:
            kw.update(sliding_window=128)
        if self.cross_attention:
            kw.update(encoder_seq=24)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name.endswith("-smoke"):
        return _REGISTRY[name.removesuffix("-smoke")].smoke()
    return _REGISTRY[name]


def list_archs(assigned_only: bool = False) -> list[str]:
    _ensure_loaded()
    names = sorted(_REGISTRY)
    if assigned_only:
        names = [n for n in names if n != "paper-cnn"]
    return names


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (  # noqa: F401
        deepseek_v2_236b, phi3_mini_3_8b, zamba2_2_7b, h2o_danube3_4b,
        qwen2_vl_72b, mamba2_370m, whisper_medium, qwen3_14b,
        qwen2_moe_a2_7b, qwen2_0_5b, paper_cnn,
    )


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the step that
    ``shape_name`` lowers (train_step / prefill_step / decode_step).

    Decode shapes include the KV-cache specs; the cache write pointer
    ``pos`` is part of the cache pytree.
    """
    import jax
    import jax.numpy as jnp

    from repro.models import kvcache  # lazy: avoid import cycle

    shp = INPUT_SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    dt = jnp.dtype(cfg.param_dtype)
    i32 = jnp.int32

    def tok(shape):
        return jax.ShapeDtypeStruct(shape, i32)

    specs: dict = {}
    if shp.kind in ("train", "prefill"):
        if cfg.embed_input:
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
            specs["labels"] = tok((B, S))
        else:
            specs["tokens"] = tok((B, S))
        if cfg.cross_attention:
            specs["enc_out"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), dt)
        if shp.kind == "train":
            # Mix2FLD device-side KD target: global average output vectors
            # (one fd_buckets-dim distribution per ground-truth bucket)
            specs["gout"] = jax.ShapeDtypeStruct(
                (cfg.fd_buckets, cfg.fd_buckets), jnp.float32)
    else:  # decode: one new token against a seq_len cache
        if cfg.embed_input:
            specs["embeds"] = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)
        else:
            specs["tokens"] = tok((B, 1))
        specs["cache"] = kvcache.cache_specs(cfg, B, S)
    return specs

