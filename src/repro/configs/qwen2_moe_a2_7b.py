"""Qwen2-MoE A2.7B — 4 shared + 60 routed experts, top-4. [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from . import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,           # routed expert intermediate (assignment sheet)
    vocab_size=151936,
    num_experts=60,
    num_shared_experts=4,
    top_k=4,
    moe_d_ff=1408,
    grad_accum=2,   # MoE dispatch tensors at train_4k: fits 16 GB/chip
))
