"""Roofline terms from the compiled dry-run (no TPU in the container):

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
are parsed out of the post-SPMD optimized HLO text (cost_analysis does not
expose them) by summing the result-shape sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# e.g.:  %all-gather.3 = bf16[16,2048]{1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(COLLECTIVES) + r")(-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,{} ]+)\}\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")


_PAIRS_RE = re.compile(r"source_target_pairs=\{([\d,{} ]+)\}")


def _crosses_pods(instr_text: str, pod_size: int) -> bool:
    """True if any replica group spans devices from different pods."""
    m = _PAIRS_RE.search(instr_text)
    if m:  # collective-permute: {s,t} pairs
        for pair in m.group(1).split("},{"):
            ids = [int(x) for x in pair.replace("{", "").replace("}", "")
                   .split(",") if x.strip()]
            if len(ids) == 2 and ids[0] // pod_size != ids[1] // pod_size:
                return True
        return False
    m = _GROUPS_IOTA_RE.search(instr_text)
    if m:
        import numpy as np
        ng, gs = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(d) for d in m.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(ng, gs)
        return bool(((groups // pod_size).min(axis=1) !=
                     (groups // pod_size).max(axis=1)).any())
    m = _GROUPS_EXPLICIT_RE.search(instr_text)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.replace("{", "").replace("}", "")
                   .split(",") if x.strip()]
            if ids and min(ids) // pod_size != max(ids) // pod_size:
                return True
        return False
    return True  # no groups listed: global collective (crosses pods)


_WHILE_RE = re.compile(
    r"while\([^)]*\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)"
    r".*?known_trip_count\":\{\"n\":\"(\d+)\"", re.DOTALL)
_WHILE_NOTRIP_RE = re.compile(
    r"while\([^)]*\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _parse_computations(hlo_text: str):
    """Split optimized HLO into computation blocks. Returns
    (blocks: name -> body text, entry_name)."""
    blocks: dict[str, list[str]] = {}
    entry = None
    cur = None
    depth = 0
    for line in hlo_text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{") and "(" in line:
                m = _HEADER_RE.match(line.strip())
                if not m:
                    continue
                cur = m.group(2)
                if m.group(1):
                    entry = cur
                blocks[cur] = []
                depth = 1
        else:
            depth += line.count("{") - line.count("}")
            if depth <= 0:
                cur = None
            else:
                blocks[cur].append(line)
    return {k: "\n".join(v) for k, v in blocks.items()}, entry


def collective_bytes_from_hlo(hlo_text: str, pod_size: int = 0) -> dict:
    """Loop-aware per-device collective bytes.

    SPMD-partitioned HLO reports shard shapes (per-device bytes), but a
    plain text scan counts each scan (``while``) body ONCE.  We recurse
    through while ops using their ``known_trip_count`` backend configs,
    so an FSDP all-gather inside the 60-layer scan counts 60 times.

    With ``pod_size`` > 0, bytes of collectives whose replica groups span
    pods are additionally reported as ``cross_pod`` (the paper's scarce
    inter-pod "uplink" direction).
    """
    blocks, entry = _parse_computations(hlo_text)

    memo: dict[str, dict] = {}

    def visit(name: str) -> dict:
        if name in memo:
            return memo[name]
        text = blocks.get(name, "")
        acc = {k: 0 for k in COLLECTIVES}
        acc["cross_pod"] = 0
        cnt = {k: 0 for k in COLLECTIVES}
        for line in text.splitlines():
            m = _OP_RE.search(line)
            if not m:
                continue
            shape_str, kind, phase = m.group(1), m.group(2), m.group(3)
            if phase == "-done":
                continue
            nbytes = _shape_bytes(shape_str)
            acc[kind] += nbytes
            cnt[kind] += 1
            if pod_size and _crosses_pods(line, pod_size):
                acc["cross_pod"] += nbytes
        # recurse into while bodies with trip counts
        seen_bodies = set()
        for m in _WHILE_RE.finditer(text):
            body, trip = m.group(2), int(m.group(3))
            seen_bodies.add(body)
            sub = visit(body)
            for k in list(COLLECTIVES) + ["cross_pod"]:
                acc[k] += trip * sub[k]
            for k in COLLECTIVES:
                cnt[k] += trip * sub["counts"][k]
        for m in _WHILE_NOTRIP_RE.finditer(text):
            body = m.group(2)
            if body in seen_bodies:
                continue
            sub = visit(body)  # unknown trip: count once (conservative)
            for k in list(COLLECTIVES) + ["cross_pod"]:
                acc[k] += sub[k]
            for k in COLLECTIVES:
                cnt[k] += sub["counts"][k]
        acc["counts"] = cnt
        memo[name] = acc
        return acc

    out = visit(entry) if entry else {k: 0 for k in COLLECTIVES} | {
        "cross_pod": 0, "counts": {k: 0 for k in COLLECTIVES}}
    out = dict(out)
    out["total"] = sum(out[k] for k in COLLECTIVES)
    return out


def analytic_flops(cfg, shape, n_active: int) -> float:
    """Whole-program FLOPs model (global, all chips).

    XLA's cost_analysis counts while bodies once, so the HLO number is a
    severe undercount for scanned layers; this analytic model is what the
    compute roofline term uses.  Training uses 8*N*D: fwd + full-remat
    re-fwd + 2x bwd (our scan remat recomputes every layer).
    """
    B, S = shape.global_batch, shape.seq_len
    train = shape.kind == "train"
    tokens = B * (S if shape.kind != "decode" else 1)
    mult = 8 if train else 2
    total = float(mult) * n_active * tokens

    # attention term
    H, hd, L = cfg.num_heads, cfg.head_dim, cfg.num_layers
    if cfg.attn_type == "mla":
        hd = cfg.head_dim + cfg.rope_head_dim
    n_attn_layers = L if cfg.family != "hybrid" else L // max(
        cfg.attn_every, 1)
    if H and n_attn_layers:
        if shape.kind == "decode":
            skv = min(S, cfg.sliding_window or S)
            att = 4.0 * B * skv * H * hd * n_attn_layers
        else:
            skv = S / 2 if cfg.sliding_window is None else min(
                S / 2, cfg.sliding_window)
            att = 4.0 * B * S * skv * H * hd * n_attn_layers
            att *= 4 if train else 1  # bwd + remat re-fwd
        total += att

    # SSD term
    if cfg.ssm_state:
        Hs, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        ck = cfg.ssm_chunk
        if shape.kind == "decode":
            ssd = 6.0 * B * Hs * N * P * L
        else:
            per_tok = 2.0 * ck * (N + P) + 6.0 * N * P
            ssd = B * S * Hs * per_tok * L
            ssd *= 4 if train else 1
        total += ssd
    return total


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float, chips: int,
                   peak_flops: float, hbm_bw: float, ici_bw: float) -> dict:
    """All three terms in seconds. ``flops``/``bytes_accessed`` are whole-
    program totals from cost_analysis (already per-device in SPMD HLO);
    ``collective_bytes`` is per-device (see above)."""
    return {
        "compute_s": flops / peak_flops,
        "memory_s": bytes_accessed / hbm_bw,
        "collective_s": collective_bytes / ici_bw,
    }


def dominant_term(terms: dict) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k]).replace("_s", "")


def improvement_hint(record: dict) -> str:
    """One sentence per (arch x shape): what would move the dominant
    roofline term down (deliverable g)."""
    dom = record.get("dominant", dominant_term(record["roofline"]))
    shape = record.get("shape", "")
    arch = record.get("arch", "")
    decode = "decode" in shape or "500k" in shape
    train = "train" in shape
    moe = "moe" in arch or "deepseek" in arch
    if dom == "collective":
        if decode:
            return ("TP-resident decode weights (drop the FSDP axis) "
                    "remove the per-layer weight gathers — measured 34x "
                    "on qwen2-vl (§Perf H2).")
        if moe:
            return ("Fewer grad-accum microbatches (gathers scale with "
                    "accum) and expert-placement that keeps top-k traffic "
                    "intra-host would cut the all-to-all+gather volume "
                    "(§Perf H1).")
        if train:
            return ("Overlap FSDP gathers with compute (XLA latency-hiding "
                    "scheduler on TPU) or re-materialise gathered weights "
                    "across microbatches.")
        return ("Shard the prefill KV over heads instead of sequence to "
                "avoid softmax-stat exchanges.")
    if dom == "memory":
        if decode:
            return ("int8 KV cache halves the cache traffic (measured "
                    "2.9x on phi3, §Perf H3); donation removes the "
                    "double-buffer.")
        return ("Lower grad-accum microbatch size or tighten the remat "
                "policy; the saved-carry stacks dominate.")
    return ("Compute-bound: raise arithmetic intensity with larger "
            "microbatches, or spill to more chips only if collectives "
            "stay sub-dominant.")


def recommend_execution(grid_size: int, num_devices: int, *,
                        avail: int,
                        compute_s: float | None = None,
                        channel_s: float | None = None,
                        min_hidden_frac: float = 0.05) -> dict:
    """Pick the round program's execution knobs — the 2-D
    ``(grid, device)`` mesh shape and the channel pipelining depth —
    from the roofline model's ordering arguments
    (``core.program.ProgramOptions`` consumes the result; the pipeline
    benchmark reports it next to the measured speedup).

    **Mesh shape.**  Grid points are embarrassingly parallel (zero
    collective bytes between them) while device-axis shards pay a psum
    per aggregation, so chips go to the grid axis first — the same
    greedy ordering ``launch.mesh.grid_mesh_shape`` implements; this
    just re-exports its auto shape at the requested chip budget.

    **Pipeline depth.**  A round is ``compute_s`` of on-chip local SGD
    plus ``channel_s`` of host-side link simulation; the two use
    disjoint resources (XLA executor vs Python dispatch), so double
    buffering hides ``min(compute_s, channel_s)`` per steady-state
    round.  Depth 2 is recommended when that hidden slice is at least
    ``min_hidden_frac`` of the serial round; depth beyond 2 never helps
    in steady state (only one round's draw can overlap one round's
    SGD), so the recommendation is always 1 or 2.  With no timings the
    depth stays 1 — the bitwise-oracle serial path.
    """
    from ..launch.mesh import grid_mesh_shape
    gs, ds = grid_mesh_shape(grid_size, num_devices, avail=avail)
    rec = {"mesh_shape": (gs, ds), "pipeline_depth": 1,
           "hidden_s": 0.0, "est_speedup": 1.0}
    if not compute_s or not channel_s:
        rec["rationale"] = ("no round timings: strict-serial depth 1 "
                            "(the bitwise oracle)")
        return rec
    serial = compute_s + channel_s
    hidden = min(compute_s, channel_s)
    rec["hidden_s"] = hidden
    rec["est_speedup"] = serial / max(compute_s, channel_s)
    if hidden >= min_hidden_frac * serial:
        rec["pipeline_depth"] = 2
        rec["rationale"] = (
            f"channel sim is {channel_s / serial:.0%} of the serial "
            f"round: double buffering hides {hidden * 1e3:.1f}ms/round "
            f"(est {rec['est_speedup']:.2f}x)")
    else:
        rec["rationale"] = (
            f"channel sim is only {channel_s / serial:.0%} of the "
            f"serial round: overlap would hide < {min_hidden_frac:.0%}, "
            f"stay serial")
    return rec


def summarize_combo(record: dict) -> str:
    t = record["roofline"]
    dom = dominant_term(t)
    return (f"{record['arch']:20s} {record['shape']:12s} "
            f"{record['mesh']:9s} "
            f"comp={t['compute_s']*1e3:9.3f}ms "
            f"mem={t['memory_s']*1e3:9.3f}ms "
            f"coll={t['collective_s']*1e3:9.3f}ms "
            f"dom={dom:10s} "
            f"useful={record.get('model_flops_ratio', float('nan')):.3f}")
