"""Roofline analysis from compiled dry-run artifacts."""
from .analysis import (collective_bytes_from_hlo, roofline_terms,
                       summarize_combo)  # noqa: F401
