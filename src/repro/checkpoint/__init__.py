"""Checkpointing: numpy ``.npz`` of a flattened pytree + JSON manifest.

No orbax/flax in the container; this is deliberately simple but
crash-safe — the contract a long-running :class:`~repro.launch.service.
FederatedService` leans on:

* **Step dirs are atomic.**  Arrays and manifest are staged into a
  ``tmp*`` scratch dir and ``os.rename``'d into ``step_XXXXXXXX`` in one
  syscall, so a step directory is either absent or complete — a crash
  mid-save can never leave a half-written checkpoint behind.
* **The ``LATEST`` pointer is atomic and advisory.**  It is written via
  temp-file + ``os.replace``; :func:`latest_step` treats a missing,
  truncated, corrupt, or stale pointer as a cache miss and falls back to
  scanning the ``step_*`` dirs, so a torn pointer degrades to a
  directory listing rather than a crashed restore.
* **Crashed saves are garbage-collected.**  The next :func:`save` sweeps
  orphaned ``tmp*`` staging entries (single-writer discipline: one
  process saves into a given ``ckpt_dir`` at a time).
* **Retention.**  ``save(..., keep=K)`` prunes all but the newest K step
  dirs after the new one lands.
* **Restores are structure-checked.**  :func:`restore` validates the
  manifest's leaf *paths* against the target tree's paths — a target
  with a coinciding leaf count and shapes but different structure raises
  a diff-listing ``ValueError`` instead of silently loading leaves into
  the wrong slots.
* :func:`restore_tree` rebuilds the saved (string-dict-keyed) tree with
  no target template and returns the JSON ``meta`` recorded at save
  time — what a restarted service uses before it knows any shapes.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np

_STEP_PREFIX = "step_"
_TMP_PREFIX = "tmp"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _step_name(step: int) -> str:
    return f"{_STEP_PREFIX}{step:08d}"


def gc_tmp(ckpt_dir: str) -> list[str]:
    """Remove orphaned ``tmp*`` staging entries left by crashed saves
    (files and dirs; ``save`` calls this before staging its own).
    Returns the removed names."""
    removed = []
    try:
        entries = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return removed
    for name in entries:
        if not name.startswith(_TMP_PREFIX):
            continue
        path = os.path.join(ckpt_dir, name)
        if os.path.isdir(path):
            shutil.rmtree(path, ignore_errors=True)
        else:
            try:
                os.remove(path)
            except OSError:
                continue
        removed.append(name)
    return removed


def steps(ckpt_dir: str) -> list[int]:
    """Sorted step numbers of the complete ``step_*`` dirs on disk (the
    rename-into-place protocol guarantees a listed dir is complete)."""
    try:
        entries = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return []
    out = []
    for name in entries:
        if not name.startswith(_STEP_PREFIX):
            continue
        if not os.path.isdir(os.path.join(ckpt_dir, name)):
            continue
        try:
            out.append(int(name[len(_STEP_PREFIX):]))
        except ValueError:
            continue
    return sorted(out)


def _write_latest(ckpt_dir: str, name: str):
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, prefix=_TMP_PREFIX)
    try:
        with os.fdopen(fd, "w") as f:
            f.write(name)
        os.replace(tmp, os.path.join(ckpt_dir, "LATEST"))
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise


def save(ckpt_dir: str, step: int, tree, *, meta: dict | None = None,
         keep: int | None = None) -> str:
    """Write one checkpoint.  ``meta`` is an arbitrary JSON-serializable
    dict stored in the manifest (round counters, accountant ledgers —
    anything that is not an array leaf).  ``keep`` retains only the
    newest ``keep`` step dirs after this one lands."""
    if keep is not None and keep < 1:
        raise ValueError(f"keep must retain at least the checkpoint "
                         f"being written, got keep={keep}")
    paths, leaves, _ = _flatten_with_paths(tree)
    os.makedirs(ckpt_dir, exist_ok=True)
    gc_tmp(ckpt_dir)
    target = os.path.join(ckpt_dir, _step_name(step))
    tmp = tempfile.mkdtemp(prefix=_TMP_PREFIX, dir=ckpt_dir)
    try:
        arrays = {f"a{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "paths": paths, "meta": meta or {}}, f)
        if os.path.isdir(target):
            shutil.rmtree(target)
        os.rename(tmp, target)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _write_latest(ckpt_dir, os.path.basename(target))
    if keep is not None:
        for old in steps(ckpt_dir)[:-keep]:
            if old != step:
                shutil.rmtree(os.path.join(ckpt_dir, _step_name(old)),
                              ignore_errors=True)
    return target


def latest_step(ckpt_dir: str) -> int | None:
    """Newest step on disk.  The ``LATEST`` pointer is consulted first;
    a missing/corrupt/stale pointer falls back to scanning the
    ``step_*`` dirs (None only when neither yields a step)."""
    try:
        with open(os.path.join(ckpt_dir, "LATEST")) as f:
            step = int(f.read().strip().split("_")[-1])
        if os.path.isdir(os.path.join(ckpt_dir, _step_name(step))):
            return step
    except (FileNotFoundError, ValueError):
        pass
    found = steps(ckpt_dir)
    return found[-1] if found else None


def _resolve_step(ckpt_dir: str, step: int | None) -> str:
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, _step_name(step))
    if not os.path.isdir(d):
        raise FileNotFoundError(f"no checkpoint dir {d}")
    return d


def _load_manifest(step_dir: str) -> dict:
    path = os.path.join(step_dir, "manifest.json")
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        raise FileNotFoundError(f"checkpoint {step_dir} has no "
                                "manifest.json") from None


def load_meta(ckpt_dir: str, step: int | None = None) -> dict:
    """The JSON ``meta`` dict recorded by :func:`save` (empty if the
    save passed none)."""
    return _load_manifest(_resolve_step(ckpt_dir, step)).get("meta", {})


def restore(ckpt_dir: str, target_tree, step: int | None = None):
    """Restore into the structure of ``target_tree``.

    The saved manifest's leaf paths must equal the target tree's leaf
    paths exactly (same names, same order); shapes are checked per leaf.
    A structural mismatch raises a ``ValueError`` listing the differing
    paths — equal leaf counts with coinciding shapes can no longer
    restore leaves into the wrong slots silently.
    """
    d = _resolve_step(ckpt_dir, step)
    saved_paths = _load_manifest(d)["paths"]
    paths, leaves, treedef = _flatten_with_paths(target_tree)
    if saved_paths != paths:
        saved_set, target_set = set(saved_paths), set(paths)
        only_ckpt = sorted(saved_set - target_set)
        only_target = sorted(target_set - saved_set)
        detail = []
        if only_ckpt:
            detail.append(f"only in checkpoint: {only_ckpt}")
        if only_target:
            detail.append(f"only in target: {only_target}")
        if not detail:
            detail.append("same leaves, different order: "
                          f"{saved_paths} vs {paths}")
        raise ValueError(
            f"checkpoint tree structure does not match the restore "
            f"target ({len(saved_paths)} vs {len(paths)} leaves); "
            + "; ".join(detail))
    data = np.load(os.path.join(d, "arrays.npz"))
    out = []
    for i, (path, tgt) in enumerate(zip(paths, leaves)):
        arr = data[f"a{i}"]
        if hasattr(tgt, "shape") and tuple(tgt.shape) != tuple(arr.shape):
            raise ValueError(f"shape mismatch at {path!r}: target "
                             f"{tuple(tgt.shape)} vs checkpoint "
                             f"{tuple(arr.shape)}")
        out.append(jax.numpy.asarray(arr, dtype=getattr(tgt, "dtype", None)))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_tree(ckpt_dir: str, step: int | None = None
                 ) -> tuple[dict, dict]:
    """Template-free restore: rebuild the saved tree as nested dicts of
    numpy arrays straight from the manifest paths, plus the ``meta``
    dict.  Only trees whose containers are string-keyed dicts round-trip
    through this (a single bare array round-trips too); that is the
    service checkpoint layout by construction."""
    d = _resolve_step(ckpt_dir, step)
    manifest = _load_manifest(d)
    saved_paths = manifest["paths"]
    data = np.load(os.path.join(d, "arrays.npz"))
    arrays = [data[f"a{i}"] for i in range(len(saved_paths))]
    if saved_paths == [""]:  # the tree was one bare array
        return arrays[0], manifest.get("meta", {})
    tree: dict = {}
    for path, arr in zip(saved_paths, arrays):
        node = tree
        parts = path.split("/")
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = arr
    return tree, manifest.get("meta", {})
