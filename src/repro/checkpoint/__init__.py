"""Checkpointing: numpy ``.npz`` of a flattened pytree + JSON treedef.

No orbax/flax in the container; this is deliberately simple but complete:
atomic writes, step-tagged directories, latest-pointer, restore onto an
arbitrary target structure (e.g. sharded params via ``jax.device_put``).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, tree) -> str:
    paths, leaves, _ = _flatten_with_paths(tree)
    os.makedirs(ckpt_dir, exist_ok=True)
    target = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir)
    try:
        arrays = {f"a{i}": np.asarray(leaf) for i, leaf in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "paths": paths}, f)
        if os.path.isdir(target):
            shutil.rmtree(target)
        os.rename(tmp, target)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    with open(os.path.join(ckpt_dir, "LATEST"), "w") as f:
        f.write(os.path.basename(target))
    return target


def latest_step(ckpt_dir: str) -> int | None:
    try:
        with open(os.path.join(ckpt_dir, "LATEST")) as f:
            return int(f.read().strip().split("_")[-1])
    except FileNotFoundError:
        return None


def restore(ckpt_dir: str, target_tree, step: int | None = None):
    """Restore into the structure of ``target_tree`` (shape/dtype checked)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    loaded = [data[f"a{i}"] for i in range(len(data.files))]
    if len(loaded) != len(leaves):
        raise ValueError(f"checkpoint has {len(loaded)} leaves, "
                         f"target has {len(leaves)}")
    out = []
    for tgt, arr in zip(leaves, loaded):
        if hasattr(tgt, "shape") and tuple(tgt.shape) != tuple(arr.shape):
            raise ValueError(f"shape mismatch {tgt.shape} vs {arr.shape}")
        out.append(jax.numpy.asarray(arr, dtype=getattr(tgt, "dtype", None)))
    return jax.tree_util.tree_unflatten(treedef, out)
