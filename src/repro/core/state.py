"""The one round-loop state: a frozen :class:`RoundState` pytree.

Historically each execution surface carried its own state shape —
``FederatedTrainer.round_once`` a mutable dict, ``make_grid_round_step``
a positional scan-carry dict, ``launch.service`` a third dict rebuilt
from checkpoint manifests.  This module collapses them: every round path
takes and returns one frozen dataclass whose fields ARE the checkpoint
manifest's keys (``launch.service`` maps them 1:1), registered as a JAX
pytree so the compiled grid scan can carry it directly.

Two layouts share the class:

* **loop path** (``FederatedTrainer`` / ``launch.service``) — host-side
  fields live: ``round`` (int), ``key`` (the run key; every per-round
  draw derives from ``fold_in(key, round)``), ``converged_round``
  (None | int), ``seeds`` (round-1 seed dict | None), ``cum_time_s``
  (float);
* **grid path** (``make_grid_round_step`` scan carry) — device-resident
  (G, ...) fields live (``dev_params``/``g_params``/``gout``/
  ``dev_gout``/``prev``/``converged_round`` as a (G,) int32), host
  fields stay None so the carry structure is scan-stable.

Transitional mapping compat: established callers (and the seed tests)
index states like dicts — ``state["round"]``, ``dict(state)``.  The
class keeps that working (``__getitem__``/``keys``/``get``; the grid
carry's historical ``"converged"`` key aliases ``converged_round``)
while new code uses attributes.  The dict surface is deprecated with the
flat-config aliases and goes away with them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax

#: Field order is the pytree flatten order AND the checkpoint manifest
#: contract — append only.
_FIELDS = ("round", "key", "g_params", "dev_params", "gout", "dev_gout",
           "prev", "converged_round", "seeds", "cum_time_s")

#: Historical key aliases accepted by the mapping surface.
_ALIASES = {"converged": "converged_round"}


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class RoundState:
    """One round path's complete resumable state (see module docstring).

    Every field is a pytree child: Nones drop out of the leaf list, so
    the loop layout (host scalars live) and the grid layout (host
    scalars None) are both valid scan/checkpoint citizens without two
    classes.
    """
    round: Any = 0
    key: Any = None
    g_params: Any = None
    dev_params: Any = None
    gout: Any = None
    dev_gout: Any = None
    prev: Any = None
    converged_round: Any = None
    seeds: Any = None
    cum_time_s: Any = 0.0

    # -- pytree ---------------------------------------------------------
    def tree_flatten(self):
        return tuple(getattr(self, f) for f in _FIELDS), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(**dict(zip(_FIELDS, children)))

    # -- construction helpers ------------------------------------------
    @classmethod
    def from_mapping(cls, m: Any) -> "RoundState":
        """Coerce a legacy state dict (or pass a RoundState through)."""
        if isinstance(m, cls):
            return m
        kw = {}
        for k, v in dict(m).items():
            kw[_ALIASES.get(k, k)] = v
        unknown = set(kw) - set(_FIELDS)
        if unknown:
            raise ValueError(f"unknown RoundState field(s) "
                             f"{sorted(unknown)}; fields: {_FIELDS}")
        return cls(**kw)

    def replace(self, **kw) -> "RoundState":
        """Functional field update (``dataclasses.replace`` shorthand)."""
        kw = {_ALIASES.get(k, k): v for k, v in kw.items()}
        return dataclasses.replace(self, **kw)

    # -- transitional mapping surface ----------------------------------
    def __getitem__(self, k: str):
        return getattr(self, _ALIASES.get(k, k))

    def get(self, k: str, default: Optional[Any] = None):
        try:
            return self[k]
        except AttributeError:
            return default

    def keys(self):
        return iter(_FIELDS)

    def __iter__(self):
        return iter(_FIELDS)

    def __contains__(self, k: str) -> bool:
        return _ALIASES.get(k, k) in _FIELDS
