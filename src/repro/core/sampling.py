"""Per-round client sampling: stateless seeded cohort draws.

The ROADMAP regime is a device *pool* far larger than any round can
train — millions of users, a sampled cohort per round (the active-subset
participation of communication-efficient FD variants, Sattler et al.).
This module is the one source of cohort randomness for every path that
selects devices:

* :class:`SamplerConfig` — fixed-size sampling: round ``p`` trains the
  ``cohort_size`` devices with the smallest per-device uniforms of the
  round's stateless stream.  The cohort is a pure function of
  ``(fed_seed, sampler_seed, round)``: no RNG state exists to
  checkpoint, a resumed run re-draws identical cohorts, and the sweep
  engine can precompute every round's cohort host-side and feed it to
  the compiled scan as a traced gather index.
* :func:`participation_uniforms` — the shared primitive: ONE uniform per
  pool device from ``np.random.default_rng([fed_seed, sampler_seed,
  round, mechanism])``.  ``launch.service.ChurnConfig`` thresholds
  uniforms of the same shape (Bernoulli churn) but under its own
  ``mechanism`` tag (:data:`MECH_CHURN` vs the sampler's
  :data:`MECH_SAMPLE`), so churn and sampling draw from *disjoint*
  streams even at identical seeds — when the sampler sub-samples a
  churned cohort, its uniforms are independent of the ones churn
  already thresholded (re-reading churn's stream conditioned the
  sampler's draws below ``p_active`` and biased the composed cohort
  toward low-index survivors).  The stream is consumed even when the
  draw is degenerate (``sample_ratio = 1`` / ``p_active = 1``), so
  nudging a ratio across 1.0 never shifts unrelated draws (the
  historical ``p_active >= 1`` early-return bug).
* :func:`participation_counts` — per-device participation totals over a
  round range, the input to participation-correct DP accounting
  (``core.privacy.GaussianAccountant``): a device's epsilon composes
  only over the rounds it released a payload.

Cohort invariants (property-tested in tests/test_sampling.py):
deterministic, sorted, duplicate-free, exactly ``cohort_size`` entries,
and nested across ratios — a device in the 10% cohort of round ``p`` is
also in the 20% cohort of round ``p`` (smallest-uniform selection).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

#: Mechanism tags folded into the participation stream seed: each
#: participation mechanism draws from its own stream, so composing them
#: (churn, then sampling over the churned cohort) never re-reads
#: uniforms another mechanism already conditioned on.
MECH_SAMPLE = 0   #: fixed-size client sampling (SamplerConfig)
MECH_CHURN = 1    #: Bernoulli device churn (launch.service.ChurnConfig)


def participation_rng(fed_seed: int, sampler_seed: int, round_: int,
                      mechanism: int = MECH_SAMPLE
                      ) -> np.random.Generator:
    """The stateless per-round participation stream — seeded by the run,
    the mechanism's seed, the 1-based round number, and the mechanism
    tag, nothing else."""
    return np.random.default_rng([int(fed_seed), int(sampler_seed),
                                  int(round_), int(mechanism)])


def participation_uniforms(fed_seed: int, sampler_seed: int, round_: int,
                           pool_size: int,
                           mechanism: int = MECH_SAMPLE
                           ) -> tuple[np.ndarray, np.random.Generator]:
    """One uniform per pool device from the round's per-mechanism
    stream, plus the generator (already advanced past the uniforms) for
    draws that need a top-up (churn's ``min_active``).  Fixed-size
    sampling and Bernoulli churn share this primitive but pass distinct
    ``mechanism`` tags, so their streams are disjoint even at identical
    seeds — composing them stays unbiased."""
    rng = participation_rng(fed_seed, sampler_seed, round_, mechanism)
    return rng.random(pool_size), rng


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """Seeded, stateless fixed-size client sampling.

    ``sample_ratio`` is the participation fraction q: each round trains
    ``cohort_size = ceil(q * pool)`` devices (clamped to
    ``[min_active, pool]``).  A fixed cohort size — unlike Bernoulli
    churn's variable one — is what lets the compiled round paths trace
    the gather once: every round of every grid point shares one
    ``(D_cohort,)`` index shape.  ``sample_ratio = 1`` disables
    sampling (the cohort is the whole pool, in order)."""
    sample_ratio: float = 1.0
    min_active: int = 1
    seed: int = 0

    def __post_init__(self):
        if not 0.0 < self.sample_ratio <= 1.0:
            raise ValueError(f"sample_ratio must be in (0, 1], "
                             f"got {self.sample_ratio}")
        if self.min_active < 1:
            raise ValueError("min_active must be >= 1: a round needs at "
                             "least one training device")

    def __call__(self) -> "SamplerConfig":
        """Transitional no-op: ``fc.sampler`` used to be a method; it is
        now the typed sub-config field itself, and legacy ``fc.sampler()``
        call sites resolve through this."""
        return self

    def cohort_size(self, pool_size: int) -> int:
        """Devices per round for a ``pool_size`` pool: ceil(q * pool),
        at least ``min_active``, at most the pool.  The 1e-9 slack
        absorbs float representation error (0.3 * 10 is 3.0000...04 in
        binary; it must mean 3 devices, not 4)."""
        want = math.ceil(self.sample_ratio * pool_size - 1e-9)
        return min(pool_size, max(want, min(self.min_active, pool_size)))

    def cohort(self, fed_seed: int, round_: int,
               pool_size: int) -> np.ndarray:
        """Sorted active-device indices of round ``round_`` — a pure
        function of (seeds, round).  The cohort is the ``cohort_size``
        devices with the smallest uniforms of the round's stream, so
        cohorts nest across ratios and the full-ratio cohort is exactly
        ``arange(pool_size)`` (bit-identical to the unsampled path)
        while still consuming the stream."""
        size = self.cohort_size(pool_size)
        u, _ = participation_uniforms(fed_seed, self.seed, round_,
                                      pool_size)
        if size >= pool_size:
            return np.arange(pool_size)
        return np.sort(np.argpartition(u, size)[:size])

    def participation_counts(self, fed_seed: int, rounds: int,
                             pool_size: int) -> np.ndarray:
        """(pool_size,) participation totals over rounds ``1..rounds`` —
        how many payloads each device actually released, the unit DP
        composition must count (see ``core.privacy``)."""
        counts = np.zeros(pool_size, np.int64)
        for p in range(1, rounds + 1):
            counts[self.cohort(fed_seed, p, pool_size)] += 1
        return counts


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    """Seeded device churn: each round, every device of the pool is
    independently active with probability ``p_active``; if fewer than
    ``min_active`` come up, the draw tops the cohort back up (still
    deterministically).  ``p_active = 1`` disables churn.

    Lives here (not ``launch.service``, which re-exports it) so
    ``FederatedConfig.churn`` can type the field without a core -> launch
    import cycle; churn and sampling are the two participation
    mechanisms of this module's stream contract anyway."""
    p_active: float = 1.0
    min_active: int = 1
    seed: int = 0

    def __post_init__(self):
        if not 0.0 < self.p_active <= 1.0:
            raise ValueError(f"p_active must be in (0, 1], "
                             f"got {self.p_active}")
        if self.min_active < 1:
            raise ValueError("min_active must be >= 1: a round needs at "
                             "least one training device")

    def active_devices(self, fed_seed: int, round_: int,
                       pool_size: int) -> np.ndarray:
        """Sorted active-device indices of round ``round_`` — a pure
        function of (seeds, round), so resumed runs re-draw identical
        cohorts without checkpointing any RNG state.

        Churn thresholds per-round participation uniforms from the same
        primitive the client sampler ranks but under its own
        ``MECH_CHURN`` stream tag, so sampling over a churned cohort
        never re-reads uniforms churn already conditioned on (sharing
        one stream biased the composed cohort toward low-index
        survivors).  The stream is consumed even when ``p_active >= 1``
        makes the draw degenerate — an early return used to skip the
        rng entirely, so nudging ``p_active`` across 1.0 shifted
        unrelated draws."""
        u, rng = participation_uniforms(fed_seed, self.seed, round_,
                                        pool_size, mechanism=MECH_CHURN)
        mask = u < self.p_active
        idx = np.flatnonzero(mask)
        want = min(self.min_active, pool_size)
        if len(idx) < want:
            inactive = np.flatnonzero(~mask)
            extra = rng.choice(inactive, size=want - len(idx),
                               replace=False)
            idx = np.concatenate([idx, extra])
        return np.sort(idx)
