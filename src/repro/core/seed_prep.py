"""Round-1 seed-prep layer: host-side seed collection, memoized.

``collect_seeds`` is the host-side half of Algorithm 1's round-1 seed
exchange (device-side Mixup draws, the sort-based ``pair_symmetric``
matcher, segment/sort label-cycle search, inverse-Mixup assembly).  It
runs once per training job on the loop path — but a sweep grid used to
re-run it once per grid point even when no seed-determining field
varied (an eta-only grid re-collected G identical seed sets).

This module factors that host prep behind a content-keyed memo:

* :func:`seed_prep_key` — the seed-determining identity of a prep call:
  the :data:`SEED_FIELDS` of the config (``protocol``, ``lam``,
  ``n_seed``, ``n_inverse``, ``seed``, plus the shape-fixing
  ``num_devices``/``num_classes``), a content fingerprint of the device
  partition, and the PRNG key bytes.
* :class:`SeedPrepMemo` + :func:`prepare_seeds` — memoized entry point;
  grid points whose keys coincide share one prep run *and* one result
  object (the sweep engine stacks shared padded seed sets by identity).
* :func:`summarize_seeds` — lightweight metadata (counts, pair count,
  cycle-length histogram) that ``FederatedTrainer.run`` stores in
  histories instead of dragging device arrays into serialized results.
* :data:`prep_stats` — a host-prep run counter; the memoization tests
  assert an eta-only grid preps exactly once.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.mixup_kernel import mixup_pallas
from .mixup import (find_label_cycles, inverse_mixup_cycles,
                    make_mixup_batch_pallas, mixup_pairs, pair_symmetric)

if TYPE_CHECKING:  # pragma: no cover - annotation only (avoids a cycle)
    from .protocols import FederatedConfig

#: Config fields that determine the round-1 seed sets.  Everything else
#: (step sizes, conversion budgets, channel fields) leaves the host prep
#: untouched, so grid points differing only there share one prep run.
SEED_FIELDS = ("protocol", "lam", "n_seed", "n_inverse", "seed",
               "num_devices", "num_classes",
               # the task fixes the seed-sample feature shape (the
               # partition fingerprint would catch a shape change too,
               # but the config half of the key must disambiguate grids
               # that sweep the task axis over one shared memo)
               "task",
               # sampling fields: round-1 seeds are collected from the
               # round-1 *cohort*, which these determine
               "sample_ratio", "sample_seed", "sample_min_active")


@dataclasses.dataclass
class PrepStats:
    """Global host-prep run counter (see ``prep_stats``)."""
    runs: int = 0

    def reset(self):
        self.runs = 0


prep_stats = PrepStats()


def partition_fingerprint(dev_x, dev_y) -> str:
    """Content digest of a device partition — the ``partition identity``
    part of the memo key.  Hashing the bytes (~ms for MNIST-sized
    partitions) is negligible next to one prep run and robust against
    id() reuse across garbage-collected arrays."""
    h = hashlib.sha1()
    for a in (dev_x, dev_y):
        a = np.asarray(a)
        h.update(str((a.shape, str(a.dtype))).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def seed_fields_key(fc) -> tuple:
    """The :data:`SEED_FIELDS` tuple of one config — the config half of
    the memo key, and the grouping key ``SweepGrid.seed_key`` exposes at
    the grid level (one definition, used by both)."""
    return tuple(getattr(fc, f) for f in SEED_FIELDS)


def seed_prep_key(fc, dev_x, dev_y, key, fingerprint: Optional[str] = None
                  ) -> tuple:
    """Content key of one prep call: seed-determining config fields +
    partition fingerprint + PRNG key bytes.  Pass a precomputed
    ``fingerprint`` to skip re-hashing the partition."""
    return (seed_fields_key(fc),
            fingerprint or partition_fingerprint(dev_x, dev_y),
            np.asarray(key).tobytes())


class SeedPrepMemo:
    """Content-keyed cache of prep results.  ``hits``/``misses`` are
    instrumentation for tests and benchmark reporting.

    The partition fingerprint is itself cached per array pair (keyed by
    id, with the arrays retained so ids stay valid for the memo's
    lifetime): a G-point grid hashes its shared partition once, so memo
    *hits* cost a dict lookup, not a full-dataset sha1.  Consequence:
    partitions handed to one memo must not be mutated in place between
    calls (jax arrays are immutable; for numpy inputs, pass a fresh
    array — or a fresh memo — when the data changes), or the stale
    fingerprint will serve the old seed set."""

    def __init__(self):
        self._cache: dict = {}
        self._fp_cache: dict = {}
        self.hits = 0
        self.misses = 0

    def _fingerprint(self, dev_x, dev_y) -> str:
        fpk = (id(dev_x), id(dev_y))
        hit = self._fp_cache.get(fpk)
        if hit is not None:
            return hit[0]
        fp = partition_fingerprint(dev_x, dev_y)
        self._fp_cache[fpk] = (fp, dev_x, dev_y)
        return fp

    def get_or_collect(self, fc, dev_x, dev_y, key):
        k = seed_prep_key(fc, dev_x, dev_y, key,
                          fingerprint=self._fingerprint(dev_x, dev_y))
        if k in self._cache:
            self.hits += 1
            return self._cache[k]
        self.misses += 1
        out = collect_seeds(fc, dev_x, dev_y, key)
        self._cache[k] = out
        return out


def prepare_seeds(fc, dev_x, dev_y, key, memo: Optional[SeedPrepMemo] = None):
    """Memoized front door to :func:`collect_seeds`.  Without a memo it
    is a plain prep run; with one, repeat calls whose seed-determining
    content coincides return the *same* result object."""
    if memo is None:
        return collect_seeds(fc, dev_x, dev_y, key)
    return memo.get_or_collect(fc, dev_x, dev_y, key)


def summarize_seeds(seeds) -> Optional[dict]:
    """Lightweight, JSON-ready metadata of one seed set: set sizes, pair
    count and the cycle-length histogram — what histories carry instead
    of the device arrays (opt back in via
    ``FederatedConfig.keep_seed_arrays``).

    ``n_pairs``/``cycle_hist`` describe the *extraction* (the
    augmentation pool before it is truncated — or, in the degenerate
    last resort, tiled — to the ``n_inverse * D`` target); their sample
    total is reported as ``n_extracted``, which therefore need not equal
    ``n_train``."""
    if seeds is None:
        return None
    hist = {str(k): int(v)  # string keys survive a JSON round-trip
            for k, v in seeds.get("cycle_hist", {}).items()}
    return {
        "n_train": int(seeds["train_x"].shape[0]),
        "n_uploaded": int(seeds["uploaded"].shape[0]),
        "n_pairs": int(seeds.get("n_pairs", 0)),
        "cycle_hist": hist,
        "n_extracted": sum(int(k) * v for k, v in hist.items()),
        "hard_labels": np.asarray(seeds["train_y"]).ndim == 1,
    }


# ---------------------------------------------------------------------------
# The host prep itself (moved verbatim from core.protocols; pairing and
# cycle search are host-side sort algorithms, run once per training job)
# ---------------------------------------------------------------------------

def collect_seeds(fc: "FederatedConfig", dev_x, dev_y, key):
    """Round-1 seed collection, batched over the device axis.

    Device-side Mixup is one vmapped ``mixup_pairs`` draw plus a single
    ``make_mixup_batch_pallas`` kernel call over all (D, n_seed)
    mixes; server-side pairing is the vectorized sort-based
    ``pair_symmetric`` over the whole (D*Ns,) upload set; the paired
    inverse-Mixup samples are computed in one shot through the
    ``mixup_pallas`` kernel (scalar ``mixup.inverse_mixup`` stays as the
    reference oracle), and cycle augmentation beyond the pair set uses
    the batched ``inverse_mixup_cycles`` contraction over segment/sort
    label cycles.  Returns dict with uploaded samples, labels (hard or
    soft), metadata, and the server-side training set.

    ``D`` comes from the data, not the config: churned service cohorts
    hand in an active subset of the device population, and the seed
    exchange covers whoever is present in round 1 (identical to
    ``fc.num_devices`` for the full-population scripts)."""
    D = jnp.asarray(dev_x).shape[0]
    C = fc.num_classes
    proto = fc.protocol
    if proto in ("fl", "fd"):
        return None
    dev_x = jnp.asarray(dev_x)
    dev_y = jnp.asarray(dev_y)
    n_local = dev_x.shape[1]
    feat = dev_x.shape[2:]
    if proto == "fld" and fc.n_seed > n_local:
        raise ValueError(
            f"n_seed={fc.n_seed} seed samples per device cannot be drawn "
            f"without replacement from n_local={n_local} local samples; "
            "reduce FederatedConfig.n_seed or give each device more data")
    if proto in ("mixfld", "mix2fld") and n_local < 2:
        raise ValueError(
            f"Mixup seed collection needs at least 2 local samples per "
            f"device to draw cross-class pairs, got n_local={n_local}")
    prep_stats.runs += 1
    keys = jax.random.split(key, D)

    if proto == "fld":  # raw samples (privacy leak, the baseline)
        idx = jax.vmap(lambda k: jax.random.choice(
            k, n_local, (fc.n_seed,), replace=False))(keys)
        seeds_x = jax.vmap(lambda x, i: x[i])(dev_x, idx)
        seeds_y = jnp.take_along_axis(dev_y, idx, axis=1)
        seeds_x = seeds_x.reshape((D * fc.n_seed,) + feat)
        return {"train_x": seeds_x, "train_y": seeds_y.reshape(-1),
                "uploaded": seeds_x, "raw_pairs": None}

    # ---- Mixup at devices (eq. 6), batched over the device axis and
    # mixed through the mixup_pallas kernel (same treatment the
    # server-side inverse gets below; jax.vmap(make_mixup_batch) is
    # the parity oracle in tests/test_kernels.py) ----
    idx_i, idx_j = jax.vmap(mixup_pairs, in_axes=(0, 0, None, None))(
        keys, dev_y, fc.n_seed, C)                     # (D, Ns) each
    mixed, softs, (minors, majors) = make_mixup_batch_pallas(
        dev_x, dev_y, idx_i, idx_j, fc.lam, C)
    gather = jax.vmap(lambda x, i: x[i])
    raws = jnp.stack([gather(dev_x, idx_i), gather(dev_x, idx_j)],
                     axis=2)                           # (D, Ns, 2, ...)
    mixed = mixed.reshape((D * fc.n_seed,) + feat)
    softs = softs.reshape(D * fc.n_seed, C)
    minors = np.asarray(minors).reshape(-1)
    majors = np.asarray(majors).reshape(-1)
    raws = raws.reshape((D * fc.n_seed, 2) + feat)
    dev_ids = np.repeat(np.arange(D), fc.n_seed)

    if proto == "mixfld":
        return {"train_x": mixed, "train_y": softs,
                "uploaded": mixed, "raw_pairs": raws}

    # ---- Mix2FLD: inverse-Mixup across devices (eq. 7, Prop. 1) ----
    if abs(2.0 * fc.lam - 1.0) < 1e-6:
        # lam = 0.5 makes the inverse ratios singular (Prop. 1);
        # degrade to soft-label training instead of dividing by zero
        return {"train_x": mixed, "train_y": softs,
                "uploaded": mixed, "raw_pairs": raws}
    pairs = pair_symmetric(minors, majors, dev_ids)    # (P, 2)
    want_total = fc.n_inverse * D
    mixed_flat = mixed.reshape(mixed.shape[0], -1)
    inv_chunks, lab_chunks = [], []
    cycle_hist: dict[int, int] = {}
    if len(pairs):
        # one batched kernel call per side: s1 = lam_hat*m_i +
        # (1-lam_hat)*m_j and its mirror, for every pair at once
        lam_hat = fc.lam / (2.0 * fc.lam - 1.0)
        a = mixed_flat[jnp.asarray(pairs[:, 0])]
        b = mixed_flat[jnp.asarray(pairs[:, 1])]
        la = jnp.full((len(pairs),), lam_hat, jnp.float32)
        s1 = mixup_pallas(a, b, la, 1.0 - la)
        s2 = mixup_pallas(b, a, la, 1.0 - la)
        inv_chunks.append(jnp.stack([s1, s2], axis=1).reshape(
            2 * len(pairs), -1))
        lab_chunks.append(np.stack([minors[pairs[:, 0]],
                                    minors[pairs[:, 1]]], 1).reshape(-1))
        cycle_hist[2] = len(pairs)
    # augmentation beyond 2*P: longer label cycles draw *distinct*
    # cyclic lam-orders (Prop. 1 rows differ with N), so extra draws
    # are new samples rather than duplicates of the pair set
    total = 2 * len(pairs)
    length = 3
    while total < want_total and length <= max(3, min(C, 6)):
        cycles = find_label_cycles(minors, majors, dev_ids, length)
        if len(cycles):
            inv_chunks.append(inverse_mixup_cycles(
                mixed_flat, cycles, fc.lam))
            lab_chunks.append(minors[cycles].reshape(-1))
            total += cycles.size
            cycle_hist[length] = len(cycles)
        length += 1
    if not inv_chunks:  # degenerate pairing: fall back to soft labels
        return {"train_x": mixed, "train_y": softs,
                "uploaded": mixed, "raw_pairs": raws}
    inv_x = jnp.concatenate(inv_chunks)
    inv_y = np.concatenate(lab_chunks)
    if inv_x.shape[0] < want_total:  # last resort: tile (explicit, old
        reps = -(-want_total // inv_x.shape[0])  # behaviour duplicated
        inv_x = jnp.tile(inv_x, (reps, 1))       # silently)
        inv_y = np.tile(inv_y, reps)
    inv_x = inv_x[:want_total].reshape((-1,) + feat)
    inv_y = jnp.asarray(inv_y[:want_total], jnp.int32)
    return {"train_x": inv_x, "train_y": inv_y,
            "uploaded": mixed, "raw_pairs": raws,
            "n_pairs": len(pairs), "cycle_hist": cycle_hist}
