"""Per-label average output vectors (eq. 2) — the FD uplink payload — and
the vocab-bucketed LM adaptation (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def label_averaged_outputs(probs, labels, num_classes: int):
    """eq. (2): F_bar[n] = mean of prob vectors over samples with label n.

    probs: (..., C) softmax outputs; labels: (...,) int.
    Returns (F_bar (num_classes, C), counts (num_classes,)).
    Rows with zero count are zeros.
    """
    flat_p = probs.reshape(-1, probs.shape[-1]).astype(jnp.float32)
    flat_y = labels.reshape(-1)
    onehot = jax.nn.one_hot(flat_y, num_classes, dtype=jnp.float32)
    sums = onehot.T @ flat_p                      # (num_classes, C)
    counts = jnp.sum(onehot, axis=0)              # (num_classes,)
    return sums / jnp.maximum(counts[:, None], 1.0), counts


def bucket_block(vocab: int, num_buckets: int) -> int:
    return -(-vocab // num_buckets)  # ceil


def bucketize_tokens(tokens, vocab: int, num_buckets: int):
    """Contiguous-block vocab bucketing for the LM adaptation (reshape-
    friendly, hence cheap and shard-friendly under pjit)."""
    return tokens // bucket_block(vocab, num_buckets)


def bucket_log_probs(logits, num_buckets: int):
    """log P(bucket) from token logits. logits: (..., V).

    Buckets are contiguous vocab blocks; log P(bucket) = logsumexp over
    the block minus logsumexp over the vocab — a reshape + two reductions.
    """
    V = logits.shape[-1]
    block = bucket_block(V, num_buckets)
    pad = num_buckets * block - V
    lf = logits.astype(jnp.float32)
    if pad:
        lf = jnp.pad(lf, [(0, 0)] * (lf.ndim - 1) + [(0, pad)],
                     constant_values=-1e30)
    lb = lf.reshape(*lf.shape[:-1], num_buckets, block)
    blse = jax.nn.logsumexp(lb, axis=-1)
    logz = jax.nn.logsumexp(blse, axis=-1, keepdims=True)
    return blse - logz
