"""RoundProgram: the one execution API every round path builds.

Four call surfaces used to drive rounds with four divergent signatures —
``FederatedTrainer.run``'s inline loop, ``FederatedTrainer.round_once``
(the serving driver's step), the sweep engine's jitted scan-over-rounds
(``_ProtocolProgram``), and ``launch.service``.  This module fronts them
with one contract:

    build a program  ->  ``step(state, xs)``  ->  ``finalize()``

* :class:`ProgramOptions` — the execution knobs that used to be
  per-caller plumbing: the 2-D ``(grid, device)`` mesh shape and the
  channel/compute pipelining depth.
* :class:`LoopRoundProgram` — the host round loop (trainer + service).
  At ``pipeline_depth > 1`` it double-buffers: round ``p``'s channel,
  outage and straggler draws are *dispatched* (``LinkPlan.dispatch``)
  up to ``depth - 1`` rounds before round ``p`` runs, so the link sim
  executes while earlier rounds' local SGD holds the chip.  Legal
  because a link outcome is a pure function of ``(plan, key)`` and the
  key of round ``q`` is ``fold_in(fold_in(run_key, q), 3)`` — known
  from round 1 — never of training state.  ``depth = 1`` is the
  strict-serial path, the bitwise oracle the ``serial_max_dev == 0``
  benchmark gate compares against.
* :class:`GridRoundProgram` — the compiled sweep program: a jitted
  ``lax.scan`` of ``make_grid_round_step``'s round step over the xs the
  engine precomputes, carrying a grid-layout :class:`RoundState`.  Here
  the channel sim is *already* inside the one fused program (the scan
  body interleaves it at the XLA level), so ``pipeline_depth`` does not
  apply; the mesh option does — the engine lays grid points along the
  ``"grid"`` axis of ``launch.mesh.make_grid_mesh``'s 2-D mesh.

The state threaded through every program is the frozen
:class:`~repro.core.state.RoundState` pytree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from .state import RoundState


@dataclasses.dataclass(frozen=True)
class ProgramOptions:
    """Execution options shared by every round program.

    ``mesh_shape`` — ``(grid_shards, device_shards)`` for the 2-D pod
    mesh (grid programs) or ``(1, device_shards)``-equivalent 1-D
    sharding (loop programs ignore the grid entry); ``None`` lets
    ``launch.mesh`` auto-shape from the available chips — or the
    roofline model pick it (``roofline.analysis.recommend_execution``).

    ``pipeline_depth`` — how many rounds of link draws may be in flight
    at once.  1 = strict serial (dispatch and collect back-to-back);
    2 = classic double buffering (round p+1's draw on the wire during
    round p's SGD).  Depth only changes *when* draws are dispatched,
    never what they return, so every depth is bitwise-identical.
    """
    mesh_shape: Optional[tuple] = None
    pipeline_depth: int = 1

    def __post_init__(self):
        if self.pipeline_depth < 1:
            raise ValueError(f"pipeline_depth must be >= 1, "
                             f"got {self.pipeline_depth}")
        if self.mesh_shape is not None:
            shape = tuple(int(s) for s in self.mesh_shape)
            if len(shape) != 2 or any(s < 1 for s in shape):
                raise ValueError(f"mesh_shape must be two positive ints "
                                 f"(grid, device), got {self.mesh_shape}")
            object.__setattr__(self, "mesh_shape", shape)


class LoopRoundProgram:
    """The host round loop behind one ``step(state, xs)`` face.

    ``xs`` is the loop path's per-round input bundle — a dict with
    ``dev_x``/``dev_y``/``test_x``/``test_y`` and optionally ``plan``
    and ``log``; data that never changes can be bound once with
    :meth:`bind` and omitted from every step.

    Double buffering (``options.pipeline_depth = d``): entering round
    ``p``, the program tops up its in-flight window so the draws of
    rounds ``p .. p + d - 1`` are dispatched, then hands round ``p``'s
    (by now usually complete) handle to ``round_once`` for collection.
    The window is keyed by round number and invalidated whenever the
    round's plan differs from the dispatched one (a cohort-size change
    under churn) — a stale handle is simply dropped, because draws are
    pure and re-drawing is cheap.
    """

    def __init__(self, trainer, options: Optional[ProgramOptions] = None):
        self.trainer = trainer
        self.options = options or ProgramOptions()
        self._bound: dict = {}
        self._pending: dict = {}   # round -> (plan, dispatch handle)
        self.dispatched = 0        # prefetches issued (bench inspects)
        self.collected = 0         # prefetches actually consumed

    def bind(self, **xs) -> "LoopRoundProgram":
        """Fix step inputs (``dev_x=..., test_x=...``) for every round."""
        self._bound.update(xs)
        return self

    # -- double-buffer window -----------------------------------------
    def _round_key(self, state: RoundState, q: int):
        return jax.random.fold_in(jax.random.fold_in(state.key, q), 3)

    def _top_up(self, state: RoundState, plan) -> None:
        """Dispatch link draws for every round in the look-ahead window
        that has none in flight yet."""
        p = state.round + 1
        for q in range(p, p + self.options.pipeline_depth):
            if q not in self._pending:
                self._pending[q] = (plan, plan.dispatch(
                    self._round_key(state, q), first_round=q == 1))
                self.dispatched += 1
        # drop handles for rounds the loop has already passed (restores)
        for q in list(self._pending):
            if q < p:
                del self._pending[q]

    def step(self, state, xs: Optional[dict] = None):
        """One round: returns ``(new_state, record)`` exactly like
        ``round_once`` — because it IS ``round_once``, plus the
        dispatch window management around it."""
        xs = {**self._bound, **(xs or {})}
        state = RoundState.from_mapping(state)
        plan = xs.get("plan")
        if plan is None:
            plan = self.trainer.link_plan(
                state.g_params, n_links=self.trainer.fc.cohort_size())
        p = state.round + 1
        self._top_up(state, plan)
        held_plan, handle = self._pending.pop(p)
        if held_plan is not plan and held_plan != plan:
            handle = None          # plan changed since dispatch: re-draw
        if handle is not None:
            self.collected += 1
        state, rec = self.trainer.round_once(
            state, xs["dev_x"], xs["dev_y"], xs["test_x"], xs["test_y"],
            plan=plan, log=xs.get("log"), _pending_link=handle)
        return state, rec

    def finalize(self) -> dict:
        """Drop any still-in-flight draws and report dispatch stats."""
        stats = {"dispatched": self.dispatched,
                 "collected": self.collected,
                 "abandoned": len(self._pending),
                 "pipeline_depth": self.options.pipeline_depth}
        self._pending.clear()
        return stats


class GridRoundProgram:
    """The sweep engine's compiled program behind the same face.

    ``step_fn(state, xs)`` is the jitted whole-grid scan (state: a
    grid-layout :class:`RoundState`; xs: the engine's stacked per-round
    arrays); ``build`` happened in the engine (tracing is its
    ``engine_stats`` counter).  ``finalize`` blocks and returns the
    scanned outputs host-side.
    """

    def __init__(self, step_fn: Callable, state0: RoundState,
                 options: Optional[ProgramOptions] = None):
        self._step_fn = step_fn
        self.options = options or ProgramOptions()
        self.state = RoundState.from_mapping(state0)
        self._out: Any = None

    def step(self, state, xs):
        """Run the compiled scan over all rounds (the grid path's unit
        of work is the whole schedule, not one round)."""
        state = RoundState.from_mapping(state)
        new_state, out = self._step_fn(state, xs)
        self.state, self._out = new_state, out
        return new_state, out

    def finalize(self):
        import numpy as np
        jax.block_until_ready(self.state.g_params)
        return self.state, jax.tree.map(np.asarray, self._out)
