"""Protocol engines: FL, FD, FLD, MixFLD, Mix2FLD (Algorithm 1).

The federated population is simulated exactly as in Sec. II: per-round
local SGD at every device, Rayleigh-faded uplink/downlink with SNR-gated
success, weighted aggregation over the successful set, and — for the FLD
family — the server-side output-to-model conversion of eq. (5).

Device-side math is jitted over the device axis on one of two paths,
selected by ``FederatedConfig.shard_devices``:

* **vmapped** (default) — the whole population on one chip, the 1-chip
  fallback and the equivalence oracle for the sharded path;
* **mesh-sharded** — the device axis is placed along the "data" axis of a
  1-D mesh (launch.mesh.make_device_mesh) and local SGD runs under
  ``shard_map`` (per-shard vmap over the local device slice); the
  cross-device reductions (weighted model average, the eq. 2 output
  average) are psum collectives, so multi-chip hosts scale the population
  with the chip count.

``FederatedTrainer.run`` keeps a host-side round loop (it mixes channel
sampling, convergence checks and tic-toc compute timing, as the paper
does).  The per-round math itself is factored into pure module-level
pieces — :func:`make_local_train`, :func:`weighted_avg`,
:func:`gout_update`, :func:`collect_seeds` — which
:func:`make_grid_round_step` recombines into a fully-traced round step
batched over a leading *config-grid* axis: the protocol-sweep engine
(``repro.sweep``) scans it over rounds so a whole hyperparameter grid
runs as one compiled program.  The sweep-vs-loop equivalence tests in
tests/test_sweep.py lock the two formulations together.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

try:  # jax >= 0.6 graduated shard_map out of experimental
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

from ..channel import ChannelConfig
from ..channel.payload import CodecSpec, LinkConfig, parse_codec
from ..channel.pipeline import (LinkPlan, channel_stage, downlink_gout,
                                downlink_params, make_uplink_stage,
                                uplink_stage)
from ..data.pipeline import TaskSpec, parse_task
from ..launch.mesh import make_device_mesh
from ..launch.sharding import federated_pspecs
from ..models.registry import ModelSpec, build_model, parse_model
# the protocol registry is the single source of truth for names; the
# historical PROTOCOLS / FLD_FAMILY module attributes stay as re-exports
from ..registry import (FLD_FAMILY, MODELS, PROTOCOLS,  # noqa: F401
                        canonical_model, canonical_protocol)
from .conversion import output_to_model, output_to_model_steps
from .losses import fd_loss
from .outputs import label_averaged_outputs
from .privacy import GaussianAccountant
from .program import LoopRoundProgram, ProgramOptions
from .sampling import ChurnConfig, SamplerConfig
from .state import RoundState
from .seed_prep import (collect_seeds, prepare_seeds,  # noqa: F401
                        summarize_seeds)


@dataclasses.dataclass
class FederatedConfig:
    protocol: str = "mix2fld"
    num_devices: int = 10          # |D|
    num_classes: Optional[int] = None  # N_L (None: the task's class count;
    #                                the registered digits task keeps the
    #                                paper's 10)
    local_iters: int = 200         # K   (paper: 6400 single-sample SGD)
    local_batch: int = 16          # samples per local SGD iteration
    server_iters: int = 160        # K_s (paper: 3200)
    server_batch: int = 16
    eta: float = 0.01
    beta: float = 0.01
    eps: float = 0.05
    lam: float = 0.1               # Mixup ratio
    n_seed: int = 10               # N_S per device
    n_inverse: int = 20            # N_I per device-equivalent (>= N_S)
    max_rounds: int = 20
    sample_bits: Optional[int] = None  # per-sample uplink payload (None:
    #                                the task's width; digits keeps the
    #                                paper's b_s = 8 bit * 28 * 28 = 6272)
    seed: int = 0
    shard_devices: bool = False    # mesh-shard the device axis (False: vmap)
    mesh_shards: int = 0           # 0 = auto (largest divisor of |D| that
    #                                fits the local chip count)
    keep_seed_arrays: bool = False  # opt-in: keep the full round-1 seed
    #                                arrays on history["seed_arrays"]
    #                                (histories otherwise carry only the
    #                                summarize_seeds metadata)
    codec: str = "identity"        # link codec: family name or spec string
    #                                ("quantize8", "dp_gaussian0.5") from
    #                                the channel.payload registry
    quant_bits: int = 8            # quantize codec: bits per element
    dp_sigma: float = 1.0          # dp_gaussian codec: noise multiplier
    dp_clip: float = 1.0           # dp_gaussian codec: L2 sensitivity clip
    dp_delta: float = 1e-5         # dp_gaussian codec: DP delta
    sample_ratio: float = 1.0      # per-round participation fraction q:
    #                                each round trains a seeded cohort of
    #                                ceil(q * num_devices) devices out of
    #                                the num_devices pool (1.0: everyone,
    #                                the paper's setting)
    sample_seed: int = 0           # cohort-draw stream seed (cohorts are
    #                                a pure function of (seed, sample_seed,
    #                                round) — see core.sampling)
    sample_min_active: int = 1     # cohort-size floor
    model: str = "cnn"             # registry model spec — a single
    #                                architecture, or a "+"-joined cohort
    #                                ("cnn+mlp+transformer") assigned to
    #                                devices round-robin (FD family only)
    task: str = "digits"           # registry task: input shape, default
    #                                class count, per-sample payload bits
    model_partition: Optional[tuple] = None  # explicit per-device
    #                                architecture names (len num_devices);
    #                                None: derived from a composite
    #                                ``model`` by cycling its parts
    # -- typed sub-configs (the canonical surface; the flat fields above
    #    are deprecated aliases kept for one release — see _sync_sub) --
    sampler: Optional[SamplerConfig] = None  # client sampling; None:
    #                                built from the sample_* aliases
    churn: Optional[ChurnConfig] = None  # device churn (read by
    #                                launch.service); None: no churn
    channel: Optional[LinkConfig] = None  # link codec; None: built from
    #                                the codec/quant/dp_* aliases.  (The
    #                                *physical* channel stays a separate
    #                                ChannelConfig argument.)

    #: flat alias -> sub-config attribute, per sub-config field.  The
    #: sub-config class defaults double as the flat-field defaults, so
    #: "was this flat alias set?" never needs a second defaults table.
    _SUB_ALIASES = {
        "sampler": (SamplerConfig, {"sample_ratio": "sample_ratio",
                                    "sample_seed": "seed",
                                    "sample_min_active": "min_active"}),
        "channel": (LinkConfig, {"codec": "codec",
                                 "quant_bits": "quant_bits",
                                 "dp_sigma": "dp_sigma",
                                 "dp_clip": "dp_clip",
                                 "dp_delta": "dp_delta"}),
    }

    def _sync_sub(self, attr: str) -> None:
        """Reconcile one typed sub-config with its flat aliases.

        Resolution order (one constructor path for old and new callers):

        * sub-config absent, aliases at defaults — build the default sub;
        * sub-config absent, aliases set — the legacy kwargs path: build
          the sub from the aliases and emit a DeprecationWarning;
        * sub-config present, aliases at defaults — canonical path; the
          aliases are synced *from* the sub so legacy readers
          (``seed_fields_key``'s getattr, sweep axis validation, tests)
          keep seeing live values;
        * both set and disagreeing — the flat aliases win and the sub is
          rebuilt.  This keeps ``dataclasses.replace(fc, sample_ratio=q)``
          (the sweep-axis mutation surface) working on configs that
          already carry sub-configs: replace hands the old sub plus the
          new flat value, and the flat edit must take effect.  Known
          limit: replace() on an alias-set config can't also swap that
          group's sub-config wholesale — set the aliases instead until
          they are removed.

        Validation itself lives in the sub-config ``__post_init__``s —
        the one site either path funnels through.
        """
        cls, aliases = self._SUB_ALIASES[attr]
        defaults = cls()
        sub = getattr(self, attr)
        flats = {f: getattr(self, f) for f in aliases}
        flats_set = any(flats[f] != getattr(defaults, aliases[f])
                        for f in aliases)
        if sub is None:
            if flats_set:
                warnings.warn(
                    f"flat FederatedConfig fields "
                    f"{sorted(f for f in aliases if flats[f] != getattr(defaults, aliases[f]))} "
                    f"are deprecated; pass {attr}={cls.__name__}(...) "
                    f"instead", DeprecationWarning, stacklevel=4)
            sub = cls(**{aliases[f]: flats[f] for f in aliases})
        elif flats_set and \
                any(flats[f] != getattr(sub, aliases[f]) for f in aliases):
            sub = cls(**{aliases[f]: flats[f] for f in aliases})
        object.__setattr__(self, attr, sub)
        for f in aliases:  # aliases mirror the sub-config, always
            object.__setattr__(self, f, getattr(sub, aliases[f]))

    def __post_init__(self):
        # data-dependent bounds (n_seed vs the per-device sample count)
        # are checked where the data is first seen: seed_prep.collect_seeds
        self.protocol = canonical_protocol(self.protocol)
        self.task = parse_task(self.task).name
        if self.num_classes is None:
            self.num_classes = self.task_spec().num_classes
        if self.sample_bits is None:
            self.sample_bits = self.task_spec().sample_bits
        # typed sub-configs reconcile (and validate) before any check
        # below reads a sampling/codec value through either surface
        self._sync_sub("sampler")
        self._sync_sub("channel")
        if self.churn is not None and not isinstance(self.churn,
                                                     ChurnConfig):
            raise TypeError(f"churn must be a ChurnConfig, "
                            f"got {type(self.churn).__name__}")
        mspec = parse_model(self.model)
        self.model = mspec.name
        if self.model_partition is None:
            if mspec.mixed:
                self.model_partition = mspec.partition(self.num_devices)
        else:
            part = tuple(canonical_model(m) for m in self.model_partition)
            if len(part) != self.num_devices:
                raise ValueError(
                    f"model_partition has {len(part)} entries for "
                    f"num_devices={self.num_devices}")
            # a uniform partition of the (single) model is just the
            # homogeneous cohort — normalize so program identity is stable
            self.model_partition = (
                None if set(part) == {self.model} else part)
        if self.model_partition is not None:
            # mixed cohorts exchange *outputs*: only the FD-family uplink
            # aggregates in the shared (C, C) output space
            if self.protocol == "fl":
                raise ValueError(
                    "protocol 'fl' aggregates parameter vectors and "
                    "cannot mix architectures; a mixed-model cohort "
                    f"({self.model!r}) needs an FD-family uplink — one "
                    "of ('fd',) + FLD_FAMILY "
                    f"{FLD_FAMILY}")
            if self.shard_devices:
                raise ValueError(
                    "mixed-architecture cohorts are not supported on the "
                    "mesh-sharded path (shard_devices=True): per-device "
                    "parameter pytrees differ across shards")
            if self.sample_ratio < 1.0:
                raise ValueError(
                    "mixed-architecture cohorts require full "
                    f"participation (sample_ratio=1.0, got "
                    f"{self.sample_ratio}): a sampled cohort would need "
                    "ragged per-architecture gathers")
        if self.n_seed < 1:
            raise ValueError(f"n_seed must be >= 1, got {self.n_seed}")
        if self.n_inverse < 1:
            raise ValueError(f"n_inverse must be >= 1, got {self.n_inverse}")
        if not 0.0 <= self.lam <= 1.0:
            raise ValueError(f"lam is a mixing ratio in [0, 1], "
                             f"got {self.lam}")

    def codec_spec(self) -> CodecSpec:
        """The resolved link codec — ``fc.channel``'s spec (the flat
        ``codec``/``quant_bits``/``dp_*`` aliases mirror its fields)."""
        return self.channel.spec()

    def cohort_size(self, pool_size: Optional[int] = None) -> int:
        """Devices training per round — ``num_devices`` unless sampling
        shrinks it.  This is the static shape every compiled round path
        sizes its device axis (and mesh, and link plan) by."""
        pool = self.num_devices if pool_size is None else pool_size
        return self.sampler.cohort_size(pool)

    def task_spec(self) -> TaskSpec:
        """The resolved task (shape / class count / payload width)."""
        return parse_task(self.task)

    def model_spec(self) -> ModelSpec:
        """The parsed model spec (``parts[0]`` is the global/server
        architecture)."""
        return parse_model(self.model)

    def server_model(self) -> str:
        """The global (server-side) architecture name."""
        return self.model_spec().parts[0]

    def model_key(self) -> str:
        """Structural model identity for program grouping: the composite
        spec name when the per-device assignment is the spec's own
        round-robin cycle, the full explicit assignment otherwise, and
        the single name for homogeneous cohorts."""
        if self.model_partition is None:
            return self.model
        parts = self.model_spec().parts
        cyc = tuple(parts[i % len(parts)] for i in range(self.num_devices))
        if tuple(self.model_partition) == cyc:
            return self.model
        return "+".join(self.model_partition)

    def arch_groups(self):
        """None for homogeneous cohorts; else the per-architecture device
        groups as ``[(name, np.int32 indices), ...]`` in first-appearance
        order over the partition (so the first group contains device 0).
        """
        if self.model_partition is None:
            return None
        part = self.model_partition
        order = list(dict.fromkeys(part))
        return [(m, np.flatnonzero(np.asarray(part) == m).astype(np.int32))
                for m in order]

    def build_models(self) -> dict:
        """Registry-built classifiers for every architecture this config
        trains (always includes the server architecture)."""
        spec_t = self.task_spec()
        names = list(self.model_partition or (self.server_model(),))
        names.append(self.server_model())
        return {m: build_model(m, spec_t.input_shape, self.num_classes)
                for m in dict.fromkeys(names)}


# ---------------------------------------------------------------------------
# Pure per-round pieces (shared by the trainer loop and the sweep engine)
# ---------------------------------------------------------------------------

def make_local_train(apply_fn, num_classes: int, local_iters: int,
                     local_batch: int):
    """Per-device local SGD (eq. 1 / 3) for one device's shard.

    ``eta``/``beta`` are *arguments* rather than baked-in constants so the
    sweep engine can vmap them over a config grid; passing the config's
    Python floats yields the same lowering as closing over them.
    ``n_loc`` bounds the batch draws — the loop path passes the static
    ``x.shape[0]``, the sweep engine a traced per-config scalar (ragged
    partitions are zero-padded to the grid maximum, and a traced bound
    equal in value to the static one draws identical indices, so pad rows
    are never sampled — same contract as the conversion's ``n_train``).
    Returns ``local_train(params, x, y, key, gout, use_kd, eta, beta,
    n_loc) -> (params, favg (C, C), cnt (C,), mean loss)``.
    """
    C = num_classes

    def local_train(params, x, y, key, gout, use_kd, eta, beta, n_loc):
        def step(carry, k):
            p, out_sum, cnt = carry
            idx = jax.random.randint(k, (local_batch,), 0, n_loc)
            xb, yb = x[idx], y[idx]

            def loss_fn(p_):
                logits = apply_fn(p_, xb)
                b = jnp.where(use_kd, beta, 0.0)
                l, _ = fd_loss(logits, yb, gout, b)
                return l, logits

            (l, logits), g = jax.value_and_grad(loss_fn, has_aux=True)(p)
            p = jax.tree.map(lambda a, b_: a - eta * b_, p, g)
            probs = jax.nn.softmax(logits, axis=-1)
            oh = jax.nn.one_hot(yb, C)
            out_sum = out_sum + oh.T @ probs
            cnt = cnt + jnp.sum(oh, axis=0)
            return (p, out_sum, cnt), l

        init = (params, jnp.zeros((C, C)), jnp.zeros((C,)))
        (params, out_sum, cnt), losses = jax.lax.scan(
            step, init, jax.random.split(key, local_iters))
        favg = out_sum / jnp.maximum(cnt[:, None], 1.0)
        return params, favg, cnt, jnp.mean(losses)

    return local_train


def make_grid_local_train(apply_fn, num_classes: int, local_iters: int,
                          local_batch: int, per_config_data: bool = False):
    """:func:`make_local_train` double-vmapped for a config grid:
    operates on (G, D, ...) device state with shared (D, ...) data — or,
    with ``per_config_data``, per-config (G, D, ...) data (heterogeneous
    partition grids; ragged ``n_local`` zero-padded to the grid maximum
    and masked by the per-config ``n_loc`` draw bound) — and per-config
    (G,) eta/beta/n_loc.  The sweep engine wraps this in shard_map for
    ``shard_devices`` grids; keeping the vmap chain here means the
    in_axes stay in one place."""
    base = make_local_train(apply_fn, num_classes, local_iters, local_batch)
    per_dev = jax.vmap(base, in_axes=(0, 0, 0, 0, 0, None, None, None, None))
    dx = 0 if per_config_data else None
    return jax.vmap(per_dev,
                    in_axes=(0, dx, dx, 0, 0, None, 0, 0, 0))


def weighted_avg(stacked, weights):
    """Weighted model average over the device axis (uplink-success set)."""
    wsum = jnp.maximum(jnp.sum(weights), 1e-9)
    return jax.tree.map(
        lambda s: jnp.tensordot(weights, s, axes=1) / wsum, stacked)


def gout_update(favg, cnt, ok):
    """eq. 2: per-class output average over the successful device set."""
    cw = ok[:, None] * cnt                  # (D, C) per-class wts
    num = jnp.einsum("dc,dcm->cm", cw, favg)
    den = jnp.sum(cw, axis=0)
    return num / jnp.maximum(den[:, None], 1.0)


def weighted_avg_psum(stacked, weights):
    """:func:`weighted_avg` for one shard of a shard_mapped device axis:
    partial tensordot over the local slice, psum over "data"."""
    wsum = jnp.maximum(jax.lax.psum(jnp.sum(weights), "data"), 1e-9)
    part = jax.tree.map(
        lambda s: jnp.tensordot(weights, s, axes=1), stacked)
    return jax.tree.map(lambda t: jax.lax.psum(t, "data") / wsum, part)


def gout_update_psum(favg, cnt, ok):
    """:func:`gout_update` with psum collectives over the "data" axis."""
    cw = ok[:, None] * cnt
    num = jax.lax.psum(jnp.einsum("dc,dcm->cm", cw, favg), "data")
    den = jax.lax.psum(jnp.sum(cw, axis=0), "data")
    return num / jnp.maximum(den[:, None], 1.0)


# Round-1 seed collection lives in core.seed_prep (host-side pairing and
# segment/sort cycle search, content-keyed memoization); ``collect_seeds``
# is re-exported above for the established import path.


class FederatedTrainer:
    """Runs one protocol over a simulated device population.

    model: an object with .init(key) and .apply(params, x) -> logits —
    or None to build ``fc.model`` from the registry for ``fc.task``'s
    geometry.  dev_x: (D, n_local, ...), dev_y: (D, n_local).

    A mixed cohort (``fc.model_partition`` set — FD family only) builds
    one classifier per architecture: every device trains its own
    parameter space, the eq. (2) aggregation merges the per-label output
    averages in the shared (C, C) output space, and the FLD conversion /
    parameter downlink act on the *server* architecture
    (``fc.server_model()``) alone — clients of other architectures keep
    learning through the KD tables, which is exactly the workload FL
    cannot express.
    """

    def __init__(self, model, fc: FederatedConfig,
                 ch: Optional[ChannelConfig] = None):
        assert fc.protocol in PROTOCOLS
        self.fc = fc
        self._arch_groups = fc.arch_groups()
        if self._arch_groups is not None:
            if model is not None:
                raise ValueError(
                    "mixed-architecture cohorts build their per-device "
                    "models from the registry; pass model=None")
            self.models = fc.build_models()
            model = self.models[fc.server_model()]
        elif model is None:
            model = fc.model_spec().build(fc.task_spec().input_shape,
                                          fc.num_classes)
        self.model = model
        self.ch = ch or ChannelConfig(num_devices=fc.num_devices)
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        fc = self.fc
        base = make_local_train(self.model.apply, fc.num_classes,
                                fc.local_iters, fc.local_batch)

        def local_train(params, x, y, key, gout, use_kd):
            # x is one device's (n_local, ...) shard under the vmap, so
            # the static shape is the exact batch-draw bound (the sweep
            # engine passes the same value as a traced per-config scalar)
            return base(params, x, y, key, gout, use_kd, fc.eta, fc.beta,
                        x.shape[0])

        vmapped = jax.vmap(local_train, in_axes=(0, 0, 0, 0, 0, None))

        apply_fn = self.model.apply

        def accuracy(params, x, y):
            logits = apply_fn(params, x)
            return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

        self._accuracy = jax.jit(accuracy)

        # link-pipeline uplink codec stage (identity: bitwise pass-through
        # that consumes no PRNG — the pre-pipeline behaviour)
        self._codec = fc.codec_spec()
        self._uplink_stage = make_uplink_stage(self._codec, fc.protocol)
        self._plan_cache = {}  # LinkPlan per cohort size (see link_plan)

        # ---- mixed cohorts: one local-train / accuracy program per
        # architecture group (device indices are static, so each group's
        # vmap spans exactly its devices) ----
        self._arch_trains = None
        if self._arch_groups is not None:
            def make_pair(apply_a):
                base_a = make_local_train(apply_a, fc.num_classes,
                                          fc.local_iters, fc.local_batch)

                def lt(params, x, y, key, gout, use_kd):
                    return base_a(params, x, y, key, gout, use_kd,
                                  fc.eta, fc.beta, x.shape[0])

                def acc_a(params, x, y):
                    logits = apply_a(params, x)
                    return jnp.mean((jnp.argmax(logits, -1) == y)
                                    .astype(jnp.float32))

                return (jax.jit(jax.vmap(
                    lt, in_axes=(0, 0, 0, 0, 0, None))), jax.jit(acc_a))

            self._arch_trains, self._arch_acc = [], {}
            for arch, idx in self._arch_groups:
                lt_a, acc_a = make_pair(self.models[arch].apply)
                self._arch_trains.append((arch, np.asarray(idx), lt_a))
                self._arch_acc[arch] = acc_a

        self.mesh = None
        if not fc.shard_devices:
            self._local_train = jax.jit(vmapped)
            self._weighted_avg = jax.jit(weighted_avg)
            self._gout_update = jax.jit(gout_update)
            return

        # ---- mesh-sharded path: device axis along the "data" mesh axis,
        # reductions as psum collectives over the shards ----
        # the mesh spans the per-round cohort, not the pool: only
        # D_cohort devices ever enter the shard_mapped fns, so a sampled
        # trainer can hold a pool far larger than the chip count
        self.mesh = make_device_mesh(fc.cohort_size(),
                                     fc.mesh_shards or None)
        ps = federated_pspecs()
        dev, rep = ps["device"], ps["replicated"]
        self._local_train = jax.jit(shard_map(
            vmapped, mesh=self.mesh,
            in_specs=(dev, dev, dev, dev, dev, rep),
            out_specs=(dev, dev, dev, dev), check_rep=False))
        self._weighted_avg = jax.jit(shard_map(
            weighted_avg_psum, mesh=self.mesh, in_specs=(dev, dev),
            out_specs=rep, check_rep=False))
        self._gout_update = jax.jit(shard_map(
            gout_update_psum, mesh=self.mesh, in_specs=(dev, dev, dev),
            out_specs=rep, check_rep=False))

    # ------------------------------------------------------------------
    def collect_seeds(self, dev_x, dev_y, key):
        """See module-level :func:`collect_seeds` (this wrapper keeps the
        established trainer API)."""
        return collect_seeds(self.fc, dev_x, dev_y, key)

    # ------------------------------------------------------------------
    def init_state(self, num_devices: Optional[int] = None) -> RoundState:
        """Fresh resumable :class:`RoundState` (see :meth:`round_once`).

        ``num_devices`` sizes the device-axis state for a churned cohort
        pool larger (or smaller) than ``fc.num_devices``; the default
        reproduces ``run``'s population exactly, including its PRNG
        stream: ``key`` is the second ``split(PRNGKey(seed))`` output,
        so round p always folds to the same round key regardless of how
        many times the loop was stopped and resumed.
        """
        fc = self.fc
        D = fc.num_devices if num_devices is None else num_devices
        C = fc.num_classes
        key = jax.random.PRNGKey(fc.seed)
        kinit, key = jax.random.split(key)
        # all devices start from a common init (paper: same architecture)
        g_params = self.model.init(kinit)
        if self._arch_groups is not None:
            # per-architecture stacks: the server architecture's group
            # shares the global init; other architectures draw from a
            # deterministic fold of the same init key
            dev_params = {}
            srv = fc.server_model()
            for arch, idx in self._arch_groups:
                init_a = g_params if arch == srv else self.models[arch].init(
                    jax.random.fold_in(kinit, MODELS.index(arch) + 1))
                dev_params[arch] = jax.tree.map(
                    lambda p: jnp.broadcast_to(
                        p, (len(idx),) + p.shape).copy(), init_a)
        else:
            dev_params = jax.tree.map(
                lambda p: jnp.broadcast_to(p, (D,) + p.shape).copy(),
                g_params)
        gout = jnp.full((C, C), 1.0 / C)
        # per-device view of gout: a device only refreshes its copy when
        # its downlink succeeds (failed links keep the previous table)
        dev_gout = jnp.broadcast_to(gout, (D, C, C))
        return RoundState(round=0, key=key, g_params=g_params,
                          dev_params=dev_params, gout=gout,
                          dev_gout=dev_gout)

    def link_plan(self, g_params, n_links: Optional[int] = None) -> LinkPlan:
        """The codec-aware link plan for an ``n_links``-device cohort,
        cached per cohort size (payload bits depend only on the model
        and config, both fixed for a trainer's lifetime)."""
        fc = self.fc
        n_links = fc.num_devices if n_links is None else n_links
        plan = self._plan_cache.get(n_links)
        if plan is None:
            n_mod = sum(p.size for p in jax.tree.leaves(g_params))
            plan = LinkPlan.build(fc.protocol, self.ch, n_mod=n_mod,
                                  n_labels=fc.num_classes,
                                  sample_bits=fc.sample_bits,
                                  n_seed=fc.n_seed, codec=self._codec,
                                  n_links=n_links)
            self._plan_cache[n_links] = plan
        return plan

    def round_once(self, state, dev_x, dev_y, test_x, test_y, *,
                   plan: Optional[LinkPlan] = None, log=None,
                   _pending_link=None):
        """One federated round — ``run``'s round body as a resumable
        step.  Returns ``(new_state, record)``.

        ``state`` is :meth:`init_state`'s :class:`RoundState` (or the
        previous round's output; a legacy mapping coerces); the round
        number and every PRNG draw derive from it, so a state rebuilt
        from a checkpoint continues the exact stream an uninterrupted
        loop would have produced.  ``dev_x``/``dev_y`` are the *device
        pool*'s shards ``(D_pool, n_local, ...)`` — the device-axis
        state in ``state`` must match, which is how the serving driver
        runs churned cohorts through the same step.

        ``_pending_link`` is the double-buffering seam (private — the
        :class:`~repro.core.program.LoopRoundProgram` is the caller): a
        ``plan.dispatch`` handle for THIS round's key, collected where
        the serial path would draw.  A handle dispatched against a plan
        this round rebuilds (cohort-size change) is discarded — link
        draws are pure functions of ``(plan, key)``, so dropping one
        costs only its wasted dispatch.

        With ``fc.sample_ratio < 1`` the round trains only the seeded
        cohort of :meth:`FederatedConfig.cohort_size` devices
        (``core.sampling.SamplerConfig``): pool-axis state is gathered
        down to the cohort before local SGD, the link plan spans
        ``D_cohort`` links, and the trained cohort rows are scattered
        back into the pool afterwards — non-participants keep their
        parameters and KD tables untouched, exactly like a failed
        downlink.  At ``sample_ratio == 1`` this path is bypassed
        entirely, so full-participation histories stay bit-identical.
        """
        fc = self.fc
        proto = fc.protocol
        state = RoundState.from_mapping(state)
        dev_x = jnp.asarray(dev_x)
        dev_y = jnp.asarray(dev_y)
        D_pool = dev_x.shape[0]
        p = state.round + 1

        t0 = time.perf_counter()
        kr = jax.random.fold_in(state.key, p)
        use_kd = proto != "fl" and p > 1  # KD once G_out exists
        dev_params, g_params = state.dev_params, state.g_params
        gout, dev_gout = state.gout, state.dev_gout
        seeds = state.seeds

        # ---- client sampling: gather the round's cohort off the pool ----
        sampler = fc.sampler
        D = sampler.cohort_size(D_pool)
        cohort = None
        pool_params = pool_gout = None
        if D < D_pool:
            cohort = sampler.cohort(fc.seed, p, D_pool)
            jdx = jnp.asarray(cohort)
            pool_params, pool_gout = dev_params, dev_gout
            dev_params = jax.tree.map(lambda a: a[jdx], dev_params)
            dev_gout = dev_gout[jdx]
            dev_x, dev_y = dev_x[jdx], dev_y[jdx]
        # a caller-supplied plan sized for a different cohort (churn on
        # top of sampling) is rebuilt for this round's link count — and
        # any prefetched draw against the old plan with it
        if plan is None or plan.n_links != D:
            plan = self.link_plan(state.g_params, n_links=D)
            _pending_link = None

        # ---- local updates (eq. 1 / 3) ----
        dkeys = jax.random.split(jax.random.fold_in(kr, 1), D)
        if self._arch_trains is None:
            dev_params, favg, cnt, mloss = self._local_train(
                dev_params, dev_x, dev_y, dkeys, dev_gout,
                jnp.asarray(use_kd))
        else:
            # per-architecture groups train in their own parameter
            # spaces; the (D, C, C) output tables reassemble in the
            # shared output space for the eq. (2) merge below.  Each
            # device consumes the same dkeys[d] it would draw in a
            # homogeneous cohort.
            C = fc.num_classes
            favg = jnp.zeros((D, C, C))
            cnt = jnp.zeros((D, C))
            mloss = jnp.zeros((D,))
            new_dp = {}
            for arch, idx, lt in self._arch_trains:
                ji = jnp.asarray(idx)
                p_a, f_a, c_a, l_a = lt(
                    dev_params[arch], dev_x[ji], dev_y[ji], dkeys[ji],
                    dev_gout[ji], jnp.asarray(use_kd))
                new_dp[arch] = p_a
                favg = favg.at[ji].set(f_a)
                cnt = cnt.at[ji].set(c_a)
                mloss = mloss.at[ji].set(l_a)
            dev_params = new_dp
        jax.block_until_ready(favg)

        # ---- seed collection (first round, FLD family) ----
        if p == 1 and proto in FLD_FAMILY:
            seeds = self.collect_seeds(dev_x, dev_y,
                                       jax.random.fold_in(kr, 2))

        # ---- link pipeline: encode -> channel -> decode ----
        # (collect the prefetched draw when the async program dispatched
        # one — same key, same plan, so bitwise the same outcome)
        if _pending_link is not None:
            link = plan.collect(_pending_link)
        else:
            link = plan.draw(jax.random.fold_in(kr, 3),
                             first_round=p == 1)
        up_ok = link["up_ok"]
        dn_ok = link["dn_ok"]
        w = up_ok.astype(np.float32) * dev_x.shape[1]  # |S_d| weights
        # uplink codec: what the server receives (identity passes the
        # arrays through untouched; stochastic codecs draw from the
        # dedicated fold_in(kr, 5) stream, leaving every pre-existing
        # PRNG consumer bit-identical)
        dev_params_rx, favg_rx = self._uplink_stage(
            dev_params, favg, jax.random.fold_in(kr, 5), dev_gout,
            g_params)

        # ---- aggregation + (FLD) conversion ----
        if proto == "fl":
            if up_ok.any():
                g_params = self._weighted_avg(dev_params_rx,
                                              jnp.asarray(w))
        else:
            if up_ok.any():
                # eq. 2 averaged over the successful device set (psum
                # collective on the sharded path)
                gout = self._gout_update(
                    favg_rx, cnt, jnp.asarray(up_ok, jnp.float32))
            if proto != "fd":
                g_params, _ = output_to_model(
                    self.model.apply, g_params, seeds["train_x"],
                    seeds["train_y"], gout, fc.server_iters,
                    fc.server_batch, fc.eta, fc.beta,
                    jax.random.fold_in(kr, 4))

        # ---- downlink stage (gated per device by dn_ok) ----
        mask = jnp.asarray(dn_ok)
        dev_gout = downlink_gout(dev_gout, gout, mask)
        if proto != "fd":
            if self._arch_groups is None:
                dev_params = downlink_params(dev_params, g_params, mask)
            else:
                # the converted global model lives in the server
                # architecture's parameter space: only that group can
                # receive it; other architectures keep training through
                # the KD tables delivered above
                srv = fc.server_model()
                for arch, idx in self._arch_groups:
                    if arch == srv:
                        dev_params = dict(dev_params)
                        dev_params[srv] = downlink_params(
                            dev_params[srv], g_params,
                            mask[jnp.asarray(idx)])

        # ---- scatter the trained cohort back into the pool ----
        if cohort is not None:
            dev_params = jax.tree.map(
                lambda pool, coh: pool.at[jdx].set(coh), pool_params,
                dev_params)
            dev_gout = pool_gout.at[jdx].set(dev_gout)

        compute_s = time.perf_counter() - t0
        cum_time = state.cum_time_s + compute_s + link["latency_s"]

        # ---- evaluation of the round's reference device: pool device 0
        # at full participation, else the cohort's first device — it
        # just trained and received the downlink, whereas a fixed
        # device 0 sits out most rounds at small sample_ratio and its
        # stale parameters would stall the reported acc ----
        ref_dev = 0 if cohort is None else int(cohort[0])
        if self._arch_groups is None:
            ref = jax.tree.map(lambda dp: dp[ref_dev], dev_params)
            acc = float(self._accuracy(ref, test_x, test_y))
        else:
            # device 0 sits at position 0 of the first (first-appearance
            # ordered) architecture group; evaluate with its own apply
            arch0 = self._arch_groups[0][0]
            ref = jax.tree.map(lambda dp: dp[0], dev_params[arch0])
            acc = float(self._arch_acc[arch0](ref, test_x, test_y))
        if log:
            log(f"[{proto}] round {p}: acc={acc:.3f} "
                f"loss={float(mloss.mean()):.3f} up_ok={up_ok.sum()}/{D} "
                f"lat={link['latency_s']*1e3:.0f}ms")

        # ---- convergence (relative change < eps) ----
        # one reference for every protocol: the global soft-label table
        # for FD, the flattened global model otherwise (a Frobenius norm
        # equals the 2-norm of the ravel, so the FD numbers are the ones
        # the pre-factoring loop produced)
        if proto == "fd":
            flat = gout.ravel()
        else:
            flat = jnp.concatenate([jnp.ravel(x) for x in
                                    jax.tree.leaves(g_params)])
        converged_round = state.converged_round
        if state.prev is not None:
            rel = float(jnp.linalg.norm(flat - state.prev) /
                        jnp.maximum(jnp.linalg.norm(state.prev), 1e-12))
            # a total-outage round leaves the global state untouched, so
            # rel == 0 means "nothing arrived", not convergence: the
            # check only counts when at least one uplink decoded (the
            # grid path's hit mask applies the same gate)
            if rel < fc.eps and converged_round is None and \
                    bool(up_ok.any()):
                converged_round = p

        new_state = RoundState(round=p, key=state.key, g_params=g_params,
                               dev_params=dev_params, gout=gout,
                               dev_gout=dev_gout, prev=flat,
                               converged_round=converged_round,
                               seeds=seeds, cum_time_s=cum_time)
        record = {"round": p, "acc": acc, "loss": float(mloss.mean()),
                  "round_latency_s": link["latency_s"],
                  "compute_s": compute_s, "cum_time_s": cum_time,
                  "uplink_ok": int(up_ok.sum()),
                  "n_straggle": int(link.get("n_straggle", 0)),
                  "n_active": D,
                  "cohort": cohort,  # None: every pool device trained
                  "link": link}
        return new_state, record

    # ------------------------------------------------------------------
    def run(self, dev_x, dev_y, test_x, test_y, log=None,
            options: Optional[ProgramOptions] = None):
        """Full protocol run. Returns history dict (per-round accuracy,
        losses, latency, cumulative wall-clock convergence time).

        A thin driver over a :class:`LoopRoundProgram` — the serving
        loop (``launch.service``) drives the same program with churned
        cohorts and checkpoints between rounds.  ``options`` selects
        mesh shape / pipelining depth; the default is the strict-serial
        depth-1 program (every depth is bitwise-identical — see
        ``core.program``).
        """
        fc = self.fc
        spec = self._codec
        state = self.init_state()
        # ---- link pipeline plan: codec-aware payload bits -> slot counts
        # (sized for the per-round cohort, the devices actually on air)
        plan = self.link_plan(state["g_params"], n_links=fc.cohort_size())
        acct = (GaussianAccountant(spec.dp_sigma, spec.dp_delta,
                                   sample_ratio=fc.sample_ratio)
                if spec.name == "dp_gaussian" else None)

        history = {"acc": [], "round_latency_s": [], "compute_s": [],
                   "cum_time_s": [], "loss": [], "uplink_ok": [],
                   "converged_round": None, "protocol": fc.protocol,
                   "model": fc.model_key(), "task": fc.task,
                   "codec": spec.name,
                   "sample_ratio": fc.sample_ratio,
                   "cohort_size": fc.cohort_size(),
                   "uplink_bits_first": plan.up_bits_first,
                   "uplink_bits": plan.up_bits,
                   "downlink_bits": plan.dn_bits}
        if acct is not None:
            history["dp_epsilon"] = []

        dev_x = jnp.asarray(dev_x)
        dev_y = jnp.asarray(dev_y)
        program = LoopRoundProgram(self, options).bind(
            dev_x=dev_x, dev_y=dev_y, test_x=test_x, test_y=test_y,
            plan=plan, log=log)
        for _ in range(fc.max_rounds):
            state, rec = program.step(state)
            if acct is not None:
                # a device spends privacy budget only on rounds it
                # released a (noised) payload — i.e. its cohort rounds
                acct.step(cohort=rec["cohort"])
                history["dp_epsilon"].append(acct.epsilon())
            for k in ("acc", "loss", "round_latency_s", "compute_s",
                      "cum_time_s", "uplink_ok"):
                history[k].append(rec[k])
        history["pipeline"] = program.finalize()
        history["converged_round"] = state.converged_round

        # histories carry lightweight seed metadata, not device arrays —
        # serialized results stay small; opt back into the raw arrays
        # with FederatedConfig.keep_seed_arrays
        history["seeds"] = summarize_seeds(state["seeds"])
        if acct is not None:
            history["dp"] = acct.ledger()
        if fc.keep_seed_arrays:
            history["seed_arrays"] = state["seeds"]
        history["final_acc"] = history["acc"][-1]
        # per-device KD tables (tests inspect)
        self.last_dev_gout = state["dev_gout"]
        return history


# ---------------------------------------------------------------------------
# Grid-batched round step (the protocol-sweep engine's compiled core)
# ---------------------------------------------------------------------------

def make_grid_round_step(model_apply, *, protocol: str, num_devices: int,
                         num_classes: int, local_iters: int,
                         local_batch: int, server_batch: int,
                         t_max_slots: int, tau_s: float,
                         dev_x, dev_y, test_x, test_y, consts: dict,
                         per_config_data: bool = False,
                         local_train_fn: Optional[Callable] = None,
                         weighted_avg_fn: Optional[Callable] = None,
                         gout_update_fn: Optional[Callable] = None,
                         codec: str = "identity",
                         cohort_size: Optional[int] = None,
                         arch_groups: Optional[list] = None):
    """Pure per-round protocol step batched over a leading config-grid
    axis — ``FederatedTrainer.run``'s round body with every host decision
    (success gating, convergence bookkeeping) expressed as masked lax ops,
    so ``jax.lax.scan`` over rounds compiles a whole G-point grid into
    one program.

    ``consts`` holds the per-config traced constants, every leaf with a
    leading grid axis G:

    ======================  ======================================
    ``key``       (G, 2)    per-config round key — the *second* output of
                            ``split(PRNGKey(seed))`` exactly as in ``run``
    ``eta, beta`` (G,)      SGD step / KD weight (local SGD *and* the
                            eq. 5 conversion, as in the loop path)
    ``s_iters``   (G,)      conversion iterations (masked to the grid max)
    ``eps``       (G,)      convergence threshold
    ``n_local``   (G,)      per-config |S_d| — the local batch-draw bound
                            and the aggregation weight (heterogeneous
                            partition grids pad ragged partitions to the
                            grid maximum; the traced bound masks the pad)
    ``n_train``   (G,)      live prefix of the padded seed sets
    ``seeds_x``   (G, N, ...), ``seeds_y`` (G, N[, C])  padded seed sets
    ``p_up, p_dn`` (G,)     per-slot link success probabilities
    ======================  ======================================

    ``dev_x``/``dev_y`` are shared (D, n, ...) data by default; with
    ``per_config_data`` they carry a leading grid axis (G, D, n, ...) —
    one (padded) partition per config.

    The scan inputs ``xs`` per round: ``p`` (scalar, 1-based round),
    ``up_slots``/``dn_slots`` (G,) decode-slot requirements, and
    ``conv_keys`` (G, K_max, 2) host-precomputed conversion step keys
    (``jax.random.split`` is not prefix-stable, so ragged per-config
    ``s_iters`` can't split in-graph and stay equal to the loop path).

    State: a grid-layout :class:`RoundState` carry — ``dev_params``
    (G, D, ...), ``g_params`` (G, ...), ``gout`` (G, C, C), ``dev_gout``
    (G, D, C, C), ``prev`` (G, P) flattened convergence reference,
    ``converged_round`` (G,) int32 (0 = not yet); the loop path's host
    fields (``round``/``key``/``seeds``/``cum_time_s``) ride as None so
    the scan carry structure is stable.

    ``local_train_fn``/``weighted_avg_fn``/``gout_update_fn`` default to
    the vmapped single-chip forms; the sweep engine substitutes
    shard_mapped variants (device axis on the "data" mesh) for
    ``shard_devices`` grids.

    ``codec`` is the link codec *family* of this program (a structural
    axis: the sweep engine compiles one program per (protocol, codec)
    group).  Non-identity codecs read their numeric parameters from
    ``consts`` — ``q_levels``/``dp_sigma``/``dp_clip``, each (G,) — so
    quantization bit widths and DP noise sweep inside one program; the
    identity codec touches neither consts nor PRNG, keeping the compiled
    graph exactly the pre-pipeline one.

    ``cohort_size`` < ``num_devices`` turns on per-round client sampling
    (a structural axis like the codec family: the engine groups points by
    cohort size).  ``xs`` then carries ``cohort`` (G, D_cohort) int32 —
    host-precomputed sorted ``SamplerConfig.cohort`` draws — and the step
    gathers pool-axis state/data down to the cohort, trains ``D_cohort``
    devices through the identical round body (local SGD, ``D_cohort``
    channel links, codec, aggregation, downlink), and scatters the
    cohort rows back into the (G, D_pool, ...) carry.  When
    ``cohort_size`` is None or covers the pool, no gather/scatter (or
    ``cohort`` input) exists in the graph at all, so full-participation
    programs stay graph-identical to the unsampled step.

    ``arch_groups`` turns on mixed-architecture cohorts (FD family,
    full participation): a list of ``(name, device_indices, apply_fn)``
    triples in first-appearance order over the device partition (so the
    first group holds device 0, and — by the round-robin assignment
    contract — the *server* architecture whose apply is
    ``model_apply``).  ``state["dev_params"]`` becomes a dict of
    per-architecture (G, D_a, ...) stacks; each group runs its own grid
    local-train, the (G, D, C, C) output tables reassemble for the
    eq. (2) merge, and the FLD parameter downlink reaches only the
    server architecture's group.  Homogeneous programs pass None and
    keep the exact pre-refactor graph.
    """
    proto = canonical_protocol(protocol)
    D, C = num_devices, num_classes
    Dc = D if cohort_size is None else min(int(cohort_size), D)
    sampled = Dc < D
    codec_spec = parse_codec(codec)
    if arch_groups is not None:
        if sampled:
            raise ValueError("mixed-architecture grid programs require "
                             "full participation")
        if proto == "fl":
            raise ValueError("protocol 'fl' cannot mix architectures")
        arch_lt = [(a, np.asarray(idx, np.int32),
                    make_grid_local_train(fn, C, local_iters, local_batch,
                                          per_config_data))
                   for a, idx, fn in arch_groups]

    if local_train_fn is None and arch_groups is None:
        # a sampled gather of shared (D, n, ...) data yields per-config
        # (G, Dc, n, ...) batches, so the grid local-train needs the
        # per-config in_axes layout even on shared-data grids
        local_train_fn = make_grid_local_train(model_apply, C, local_iters,
                                               local_batch,
                                               per_config_data or sampled)
    if weighted_avg_fn is None:
        weighted_avg_fn = jax.vmap(weighted_avg)
    if gout_update_fn is None:
        gout_update_fn = jax.vmap(gout_update)

    def conv_one(params, sx, sy, gout, keys, iters, n_train, eta, beta):
        return output_to_model_steps(model_apply, params, sx, sy, gout,
                                     keys, iters, n_train, server_batch,
                                     eta, beta)

    conv_fn = jax.vmap(conv_one)

    # the reference device for evaluation is device 0 — in a mixed
    # cohort that is the first group's architecture, not necessarily the
    # server's
    ref_apply = arch_groups[0][2] if arch_groups is not None else model_apply

    def acc_one(params):
        logits = ref_apply(params, test_x)
        return jnp.mean((jnp.argmax(logits, -1) == test_y)
                        .astype(jnp.float32))

    acc_fn = jax.vmap(acc_one)

    def flatten_grid(tree):
        return jnp.concatenate(
            [x.reshape(x.shape[0], -1) for x in jax.tree.leaves(tree)],
            axis=1)

    channel_fn = jax.vmap(channel_stage,
                          in_axes=(0, 0, 0, 0, 0, None, None, None))
    codec_fn = jax.vmap(
        lambda dp, fa, k, dg, gp, lv, sg, cl: uplink_stage(
            codec_spec, proto, dp, fa, k, dg, gp, lv, sg, cl))

    def round_step(state, xs):
        p = xs["p"]
        kr = jax.vmap(jax.random.fold_in, in_axes=(0, None))(
            consts["key"], p)
        use_kd = (p > 1) if proto != "fl" else jnp.asarray(False)

        # ---- client sampling: gather the round's cohort (G, Dc, ...)
        # off the (G, D, ...) pool carry ----
        pool_params, pool_gout = state.dev_params, state.dev_gout
        if sampled:
            chrt = xs["cohort"]                          # (G, Dc) int32
            take = jax.vmap(lambda a, i: a[i])
            dev_params = jax.tree.map(lambda a: take(a, chrt),
                                      pool_params)
            dev_gout = take(pool_gout, chrt)
            if per_config_data:
                dx, dy = take(dev_x, chrt), take(dev_y, chrt)
            else:
                dx, dy = dev_x[chrt], dev_y[chrt]        # (G, Dc, n, ...)
        else:
            dev_params, dev_gout = pool_params, pool_gout
            dx, dy = dev_x, dev_y

        # ---- local updates (eq. 1 / 3) ----
        dkeys = jax.vmap(
            lambda k: jax.random.split(jax.random.fold_in(k, 1), Dc))(kr)
        if arch_groups is None:
            dev_params, favg, cnt, mloss = local_train_fn(
                dev_params, dx, dy, dkeys, dev_gout,
                use_kd, consts["eta"], consts["beta"], consts["n_local"])
        else:
            # per-architecture groups train their own (G, D_a, ...)
            # stacks; outputs reassemble on the full device axis so the
            # eq. (2) merge below sees the whole cohort.  dkeys spans all
            # D devices, so each device draws the stream a homogeneous
            # cohort would give it.
            G = consts["key"].shape[0]
            favg = jnp.zeros((G, D, C, C))
            cnt = jnp.zeros((G, D, C))
            mloss = jnp.zeros((G, D))
            new_dp = {}
            for arch, idx, lt in arch_lt:
                ji = jnp.asarray(idx)
                dx_a = dx[:, ji] if per_config_data else dx[ji]
                dy_a = dy[:, ji] if per_config_data else dy[ji]
                p_a, f_a, c_a, l_a = lt(
                    dev_params[arch], dx_a, dy_a, dkeys[:, ji],
                    dev_gout[:, ji], use_kd, consts["eta"],
                    consts["beta"], consts["n_local"])
                new_dp[arch] = p_a
                favg = favg.at[:, ji].set(f_a)
                cnt = cnt.at[:, ji].set(c_a)
                mloss = mloss.at[:, ji].set(l_a)
            dev_params = new_dp

        # ---- channel (batched SNR/outage draws over the grid) ----
        ck = jax.vmap(lambda k: jax.random.fold_in(k, 3))(kr)
        link = channel_fn(ck, consts["p_up"], xs["up_slots"],
                          consts["p_dn"], xs["dn_slots"], Dc, t_max_slots,
                          tau_s)
        up_ok = link["up_ok"]                        # (G, Dc)
        dn_ok = link["dn_ok"]                        # (G, Dc)
        w = up_ok.astype(jnp.float32) * \
            consts["n_local"].astype(jnp.float32)[:, None]
        any_up = jnp.any(up_ok, axis=1)              # (G,)

        # ---- uplink codec stage (same stage function as the loop path,
        # vmapped over the grid; identity skips it entirely so identity
        # programs stay graph-identical to the pre-pipeline step) ----
        if codec_spec.name == "identity":
            dev_params_rx, favg_rx = dev_params, favg
        else:
            kc = jax.vmap(lambda k: jax.random.fold_in(k, 5))(kr)
            dev_params_rx, favg_rx = codec_fn(
                dev_params, favg, kc, dev_gout,
                state.g_params, consts["q_levels"],
                consts["dp_sigma"], consts["dp_clip"])

        # ---- aggregation + (FLD) conversion, success-gated by where ----
        g_params, gout = state.g_params, state.gout
        if proto == "fl":
            new_g = weighted_avg_fn(dev_params_rx, w)
            g_params = jax.tree.map(
                lambda n_, o: jnp.where(
                    any_up.reshape((-1,) + (1,) * (o.ndim - 1)), n_, o),
                new_g, g_params)
        else:
            new_gout = gout_update_fn(favg_rx, cnt,
                                      up_ok.astype(jnp.float32))
            gout = jnp.where(any_up[:, None, None], new_gout, gout)
            if proto != "fd":
                g_params, _ = conv_fn(
                    g_params, consts["seeds_x"], consts["seeds_y"], gout,
                    xs["conv_keys"], consts["s_iters"], consts["n_train"],
                    consts["eta"], consts["beta"])

        # ---- downlink stage (gated per device by dn_ok) ----
        dev_gout = downlink_gout(dev_gout, gout, dn_ok)
        if proto != "fd":
            if arch_groups is None:
                dev_params = downlink_params(dev_params, g_params, dn_ok)
            else:
                # the converted global model is server-architecture
                # parameters: only that group (the first, by the
                # round-robin contract) receives it
                a0, i0 = arch_lt[0][0], jnp.asarray(arch_lt[0][1])
                dev_params = dict(dev_params)
                dev_params[a0] = downlink_params(
                    dev_params[a0], g_params, dn_ok[:, i0])

        # ---- scatter the trained cohort back into the pool carry ----
        if sampled:
            scatter = jax.vmap(lambda pool, i, coh: pool.at[i].set(coh))
            dev_params = jax.tree.map(
                lambda pool, coh: scatter(pool, chrt, coh), pool_params,
                dev_params)
            dev_gout = scatter(pool_gout, chrt, dev_gout)

        # ---- evaluation of the round's reference device: pool device 0
        # at full participation, else each config's first cohort device
        # (mirrors the loop path — a fixed device 0 goes stale under
        # sampling) ----
        if sampled:
            ref = jax.tree.map(
                lambda dp: jax.vmap(lambda a, i: a[i])(dp, chrt[:, 0]),
                dev_params)
        elif arch_groups is not None:
            # device 0 = position 0 of the first architecture group
            ref = jax.tree.map(lambda dp: dp[:, 0],
                               dev_params[arch_lt[0][0]])
        else:
            ref = jax.tree.map(lambda dp: dp[:, 0], dev_params)
        acc = acc_fn(ref)

        # ---- convergence (relative change < eps), first hit recorded ----
        if proto == "fd":
            flat = gout.reshape(gout.shape[0], -1)
        else:
            flat = flatten_grid(g_params)
        rel = jax.vmap(
            lambda a, b: jnp.linalg.norm(a - b) /
            jnp.maximum(jnp.linalg.norm(b), 1e-12))(flat, state.prev)
        # any_up mirrors the loop path's total-outage gate: an untouched
        # global state (rel == 0) on a round where nothing decoded is
        # not convergence
        hit = (p >= 2) & (rel < consts["eps"]) & any_up & \
            (state.converged_round == 0)
        converged = jnp.where(hit, p, state.converged_round)

        out = {"acc": acc, "loss": jnp.mean(mloss, axis=1),
               "latency_s": link["latency_s"],
               "up_ok": jnp.sum(up_ok, axis=1).astype(jnp.int32)}
        new_state = state.replace(
            dev_params=dev_params, g_params=g_params, gout=gout,
            dev_gout=dev_gout, prev=flat, converged_round=converged)
        return new_state, out

    return round_step
