"""Server-side output-to-model conversion (eq. 5, Algorithm 1 line 10).

The server transfers the knowledge in the global average output vectors
G_out into the global model by running K_s SGD-with-KD iterations over the
collected (and for Mix2FLD, inversely mixed-up) seed samples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .losses import cross_entropy, kd_regularizer


@functools.partial(jax.jit, static_argnums=(0, 5, 6, 7))
def output_to_model(model_apply, params, seeds_x, seeds_y, gout,
                    iters: int, batch: int, eta: float, beta: float, key=None):
    """K_s iterations of eq. (5). seeds_y can be int labels (FLD, Mix2FLD
    hard labels) or soft label vectors (MixFLD).  KD target row is chosen
    by the (arg-max for soft) ground-truth label.
    Returns (params, losses (iters,))."""
    key = key if key is not None else jax.random.PRNGKey(0)
    hard = seeds_y.ndim == 1
    n = seeds_x.shape[0]

    def step(carry, k):
        p = carry
        idx = jax.random.randint(k, (batch,), 0, n)
        xb, yb = seeds_x[idx], seeds_y[idx]

        def loss_fn(p_):
            logits = model_apply(p_, xb)
            phi = cross_entropy(logits, yb)
            row = yb if hard else jnp.argmax(yb, axis=-1)
            psi = kd_regularizer(logits, gout[row])
            return phi + beta * psi

        l, g = jax.value_and_grad(loss_fn)(p)
        p = jax.tree.map(lambda a, b: a - eta * b, p, g)
        return p, l

    params, losses = jax.lax.scan(step, params, jax.random.split(key, iters))
    return params, losses
