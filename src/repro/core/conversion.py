"""Server-side output-to-model conversion (eq. 5, Algorithm 1 line 10).

The server transfers the knowledge in the global average output vectors
G_out into the global model by running K_s SGD-with-KD iterations over the
collected (and for Mix2FLD, inversely mixed-up) seed samples.

Two entry points share the same per-step math:

* :func:`output_to_model` — the single-config path (static ``iters``)
  used by ``FederatedTrainer.run``.  ``key`` is **required**: the old
  silent ``PRNGKey(0)`` default made every caller that omitted it draw
  identical batch sequences across rounds and configs.
* :func:`output_to_model_steps` — the grid path for the protocol-sweep
  engine: the scan length is the grid-wide maximum ``max(iters)`` and a
  per-config ``iters`` mask turns trailing steps into no-ops, so configs
  with different conversion budgets share one compiled scan.  The step
  keys are precomputed host-side (``jax.random.split`` is not
  prefix-stable across different split counts), which keeps every live
  step bitwise-equal to the single-config path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .losses import cross_entropy, kd_regularizer


def _conversion_step(model_apply, seeds_x, seeds_y, gout, n_train, batch,
                     eta, beta, params, key):
    """One eq. (5) SGD-with-KD step shared by both conversion paths.
    Returns (updated params, loss)."""
    hard = seeds_y.ndim == 1
    idx = jax.random.randint(key, (batch,), 0, n_train)
    xb, yb = seeds_x[idx], seeds_y[idx]

    def loss_fn(p_):
        logits = model_apply(p_, xb)
        phi = cross_entropy(logits, yb)
        row = yb if hard else jnp.argmax(yb, axis=-1)
        psi = kd_regularizer(logits, gout[row])
        return phi + beta * psi

    l, g = jax.value_and_grad(loss_fn)(params)
    return jax.tree.map(lambda a, b: a - eta * b, params, g), l


@functools.partial(jax.jit, static_argnums=(0, 5, 6))
def output_to_model(model_apply, params, seeds_x, seeds_y, gout,
                    iters: int, batch: int, eta, beta, key):
    """K_s iterations of eq. (5). seeds_y can be int labels (FLD, Mix2FLD
    hard labels) or soft label vectors (MixFLD).  KD target row is chosen
    by the (arg-max for soft) ground-truth label.  ``key`` is required —
    there is deliberately no default (see module docstring).
    Returns (params, losses (iters,))."""
    n = seeds_x.shape[0]

    def step(carry, k):
        return _conversion_step(model_apply, seeds_x, seeds_y, gout, n,
                                batch, eta, beta, carry, k)

    params, losses = jax.lax.scan(step, params, jax.random.split(key, iters))
    return params, losses


def output_to_model_steps(model_apply, params, seeds_x, seeds_y, gout,
                          step_keys, iters, n_train, batch: int, eta, beta):
    """Masked-scan conversion for one config of a sweep grid.

    ``step_keys``: (K_max, 2) uint32 — the per-step PRNG keys, padded to
    the grid-wide maximum scan length (entries at index >= ``iters`` are
    never consumed); build them host-side as
    ``jax.random.split(base_key, iters)`` plus padding so live steps match
    :func:`output_to_model` exactly.  ``iters`` and ``n_train`` (the live
    prefix of a padded seed set — `randint` never samples pad rows) are
    traced per-config scalars; the caller vmaps this function over the
    grid axis.  Returns (params, losses (K_max,)) with masked steps
    contributing loss 0.
    """

    def step(carry, inp):
        k, i = inp
        new, l = _conversion_step(model_apply, seeds_x, seeds_y, gout,
                                  n_train, batch, eta, beta, carry, k)
        live = i < iters
        params = jax.tree.map(lambda a, b: jnp.where(live, a, b), new, carry)
        return params, jnp.where(live, l, 0.0)

    k_max = step_keys.shape[0]
    params, losses = jax.lax.scan(
        step, params, (step_keys, jnp.arange(k_max)))
    return params, losses
