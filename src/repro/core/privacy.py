"""Sample privacy metric (Sec. IV, Tables II/III).

privacy(s_hat) = log( min_i || s_hat - s_raw_i || )  — the log of the
minimum L2 distance between an uploaded (mixed / inversely mixed) sample
and any of its raw constituents [11], [12].  Higher = more private.
"""
from __future__ import annotations

import jax.numpy as jnp


def sample_privacy(uploaded, raws):
    """uploaded: (N, ...) uploaded samples; raws: (N, R, ...) — the R raw
    samples each uploaded sample must be compared against.
    Returns (N,) log-min-distances."""
    n = uploaded.shape[0]
    u = uploaded.reshape(n, 1, -1)
    r = raws.reshape(n, raws.shape[1], -1)
    d = jnp.linalg.norm(u - r, axis=-1)  # (N, R)
    return jnp.log(jnp.maximum(jnp.min(d, axis=-1), 1e-12))


def mean_privacy(uploaded, raws) -> float:
    return float(jnp.mean(sample_privacy(uploaded, raws)))
