"""Privacy metrics and mechanisms (Sec. IV Tables II/III; DP uplink).

Two kinds of privacy live here:

* **Sample privacy** (the paper's metric): ``sample_privacy`` scores an
  uploaded (mixed / inversely mixed) sample by the log of its minimum L2
  distance to any raw constituent [11], [12].  Higher = more private.

* **Differential privacy** (the ``dp_gaussian`` link codec, à la Hu et
  al., *Differentially Private Over-the-Air Federated Distillation*):
  :func:`gaussian_mechanism` clips a payload to a fixed L2 sensitivity
  and adds calibrated Gaussian noise before it crosses the uplink, and
  :class:`GaussianAccountant` tracks the cumulative (epsilon, delta)
  spend over rounds.  The accountant uses the classic Gaussian-mechanism
  calibration — one release with noise multiplier sigma is
  (eps0, delta)-DP for ``eps0 = sqrt(2 ln(1.25/delta)) / sigma`` (valid
  for eps0 <= 1) — under basic (linear) composition, so epsilon after T
  rounds is exactly ``T * eps0``: closed-form, and strictly monotone in
  rounds.  Tighter accountants (RDP/moments) plug in behind the same
  ``epsilon(rounds)`` surface.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np


def sample_privacy(uploaded, raws):
    """uploaded: (N, ...) uploaded samples; raws: (N, R, ...) — the R raw
    samples each uploaded sample must be compared against.
    Returns (N,) log-min-distances."""
    n = uploaded.shape[0]
    u = uploaded.reshape(n, 1, -1)
    r = raws.reshape(n, raws.shape[1], -1)
    d = jnp.linalg.norm(u - r, axis=-1)  # (N, R)
    return jnp.log(jnp.maximum(jnp.min(d, axis=-1), 1e-12))


def mean_privacy(uploaded, raws) -> float:
    return float(jnp.mean(sample_privacy(uploaded, raws)))


# ---------------------------------------------------------------------------
# Differential privacy: the dp_gaussian codec's mechanism + accountant
# ---------------------------------------------------------------------------

def clip_by_norm(x, clip):
    """Scale ``x`` so its global L2 norm is at most ``clip`` (the fixed
    sensitivity of one device's uplink payload)."""
    nrm = jnp.linalg.norm(jnp.ravel(x))
    return x * jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12))


def gaussian_mechanism(x, key, sigma, clip):
    """Clip ``x`` to L2 norm ``clip`` and add N(0, (sigma*clip)^2) noise
    per element — one (eps0, delta)-DP release of a device payload.
    ``sigma``/``clip`` may be Python floats or traced scalars (the sweep
    engine vmaps them over a config grid)."""
    noise = sigma * clip * jax.random.normal(key, x.shape, x.dtype)
    return clip_by_norm(x, clip) + noise


def gaussian_mechanism_tree(tree, key, sigma, clip):
    """:func:`gaussian_mechanism` for a pytree payload (a model update):
    the clip bounds the *global* L2 norm across leaves, noise is drawn
    per leaf from per-leaf fold_in keys."""
    leaves, treedef = jax.tree.flatten(tree)
    nrm = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in leaves))
    scale = jnp.minimum(1.0, clip / jnp.maximum(nrm, 1e-12))
    out = [x * scale + sigma * clip *
           jax.random.normal(jax.random.fold_in(key, i), x.shape, x.dtype)
           for i, x in enumerate(leaves)]
    return jax.tree.unflatten(treedef, out)


def gaussian_epsilon(sigma: float, delta: float, rounds: int = 1) -> float:
    """Closed-form epsilon of ``rounds`` Gaussian releases with noise
    multiplier ``sigma`` under basic composition:
    ``rounds * sqrt(2 ln(1.25/delta)) / sigma``."""
    if sigma <= 0:
        raise ValueError(f"dp_gaussian needs sigma > 0, got {sigma}")
    if not 0.0 < delta < 1.0:
        raise ValueError(f"delta must be in (0, 1), got {delta}")
    return rounds * math.sqrt(2.0 * math.log(1.25 / delta)) / sigma


@dataclasses.dataclass
class GaussianAccountant:
    """Per-round (epsilon, delta) ledger for the ``dp_gaussian`` uplink
    codec.  ``step()`` once per round that released a noised payload;
    ``epsilon()`` is the cumulative spend so far (monotone in rounds,
    equal to :func:`gaussian_epsilon` by construction).

    Under client sampling / churn a device releases a payload only on
    rounds it participates in, so charging every device for every round
    over-reports per-device epsilon by 1/q.  ``step(cohort=...)`` with
    the round's active-device indices records per-device participation
    counts; :meth:`epsilon_device_max` then composes over the busiest
    device's *own* rounds only.  Without cohort information the
    accountant stays conservative: every device is assumed present every
    round and the per-device bound collapses to the global one.
    ``sample_ratio`` records the sampling fraction q for
    amplification-aware reporting (the linear bound here does not take
    the subsampling amplification discount — a tighter RDP accountant
    would).

    Participation lives in :attr:`device_counts`, a dense int64 array
    indexed by device (grown on demand to the highest index seen), so a
    ``step`` is one vectorized ``np.add.at`` — O(cohort) numpy, not an
    O(cohort) Python dict loop, which matters at 10^5–10^6 device
    pools.  :attr:`device_rounds` exposes the same information as a
    ``{device: rounds}`` dict of the nonzero entries."""
    sigma: float
    delta: float = 1e-5
    rounds: int = 0
    sample_ratio: float = 1.0
    #: (pool,) per-device participation counts; empty until a cohort is
    #: recorded.  Indexed by device id, dense — checkpoints store it as
    #: a flat int list, not a str-keyed JSON dict.
    device_counts: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))

    def __post_init__(self):
        # validate eagerly: a bad sigma/delta should fail at config
        # time, not on the first epsilon() query after training
        gaussian_epsilon(self.sigma, self.delta, 1)
        self.device_counts = np.asarray(self.device_counts, np.int64)

    @property
    def epsilon_per_round(self) -> float:
        return gaussian_epsilon(self.sigma, self.delta, 1)

    @property
    def device_rounds(self) -> dict:
        """``{device: rounds}`` view of the nonzero participation
        counts (the pre-array accountant's ledger format)."""
        (nz,) = np.nonzero(self.device_counts)
        return {int(d): int(self.device_counts[d]) for d in nz}

    @device_rounds.setter
    def device_rounds(self, mapping: dict):
        if not mapping:
            self.device_counts = np.zeros(0, np.int64)
            return
        counts = np.zeros(max(int(d) for d in mapping) + 1, np.int64)
        for d, c in mapping.items():
            counts[int(d)] = int(c)
        self.device_counts = counts

    def step(self, n: int = 1, cohort=None) -> "GaussianAccountant":
        """Record ``n`` rounds of release.  ``cohort`` is the rounds'
        active-device index array (None: participation unknown — every
        device charged, the pre-sampling behaviour)."""
        self.rounds += n
        if cohort is not None:
            idx = np.asarray(cohort, np.int64).ravel()
            if idx.size:
                hi = int(idx.max()) + 1
                if hi > self.device_counts.size:
                    self.device_counts = np.concatenate(
                        [self.device_counts,
                         np.zeros(hi - self.device_counts.size,
                                  np.int64)])
                np.add.at(self.device_counts, idx, n)
        return self

    def device_rounds_max(self) -> int:
        """Rounds of the most-participating device — ``rounds`` when no
        cohorts were recorded (conservative full participation)."""
        if not self.device_counts.size:
            return self.rounds
        return int(self.device_counts.max())

    def epsilon(self, rounds: int | None = None) -> float:
        return gaussian_epsilon(self.sigma, self.delta,
                                self.rounds if rounds is None else rounds)

    def epsilon_device_max(self) -> float:
        """Worst per-device epsilon: composition over the rounds the
        busiest device actually participated in."""
        r = self.device_rounds_max()
        return self.epsilon(r) if r else 0.0

    def ledger(self) -> dict:
        """JSON-ready accountant state for histories/result frames."""
        return {"sigma": self.sigma, "delta": self.delta,
                "rounds": self.rounds,
                "epsilon_per_round": self.epsilon_per_round,
                "epsilon": self.epsilon() if self.rounds else 0.0,
                "sample_ratio": self.sample_ratio,
                "participating_devices": (
                    int((self.device_counts > 0).sum())
                    if self.device_counts.size else None),
                "device_rounds_max": self.device_rounds_max(),
                "epsilon_device_max": self.epsilon_device_max()}
