"""Losses: cross-entropy phi (eq. 1) and the KD regularizer psi (eq. 3/5).

The paper writes psi = sum_m G_m log F_m; as a *loss* to descend this is
the cross-entropy between the global average output G and the local
prediction F (we use the conventional -sum G log F; the sign in the letter
is a typo — descending +sum G log F would push F *away* from G).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.distill_loss import distill_phi_psi


def cross_entropy(logits, labels, num_classes=None):
    """phi: mean CE. logits (..., C); labels int (...,) or one-hot/soft."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    if jnp.issubdtype(labels.dtype, jnp.integer):
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll)
    return -jnp.mean(jnp.sum(labels * logp, axis=-1))


def kd_regularizer(logits, target_probs):
    """psi: CE between teacher distribution and student prediction.
    logits (..., C); target_probs (..., C) (rows of G_out)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(target_probs * logp, axis=-1))


def fd_loss(logits, labels, gout, beta: float, *, use_kernel=None):
    """eq. (3)/(5): phi + beta * psi, with the KD target row selected by the
    ground-truth label.  gout: (C, C) — row n is the global average output
    vector for ground-truth label n.

    On the local-SGD hot path (2-D logits, integer labels) both phi and psi
    dispatch through the fused ``distill_phi_psi`` Pallas kernel pair
    (forward and backward; interpret off-TPU).  ``use_kernel=False`` forces
    the pure-jnp reference — the oracle the kernel-parity tests check value
    and gradient against.  Soft labels always take the reference path.
    """
    if use_kernel is None:
        use_kernel = (logits.ndim == 2 and labels.ndim == 1
                      and jnp.issubdtype(labels.dtype, jnp.integer))
    if use_kernel:
        phi_s, psi_s = distill_phi_psi(logits, labels, gout[labels])
        phi, psi = jnp.mean(phi_s), jnp.mean(psi_s)
        return phi + beta * psi, (phi, psi)
    phi = cross_entropy(logits, labels)
    target = gout[labels]  # (..., C)
    psi = kd_regularizer(logits, target)
    return phi + beta * psi, (phi, psi)
