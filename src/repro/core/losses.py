"""Losses: cross-entropy phi (eq. 1) and the KD regularizer psi (eq. 3/5).

The paper writes psi = sum_m G_m log F_m; as a *loss* to descend this is
the cross-entropy between the global average output G and the local
prediction F (we use the conventional -sum G log F; the sign in the letter
is a typo — descending +sum G log F would push F *away* from G).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, num_classes=None):
    """phi: mean CE. logits (..., C); labels int (...,) or one-hot/soft."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    if labels.dtype in (jnp.int32, jnp.int64):
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return -jnp.mean(ll)
    return -jnp.mean(jnp.sum(labels * logp, axis=-1))


def kd_regularizer(logits, target_probs):
    """psi: CE between teacher distribution and student prediction.
    logits (..., C); target_probs (..., C) (rows of G_out)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(target_probs * logp, axis=-1))


def fd_loss(logits, labels, gout, beta: float):
    """eq. (3)/(5): phi + beta * psi, with the KD target row selected by the
    ground-truth label.  gout: (C, C) — row n is the global average output
    vector for ground-truth label n."""
    phi = cross_entropy(logits, labels)
    target = gout[labels]  # (..., C)
    psi = kd_regularizer(logits, target)
    return phi + beta * psi, (phi, psi)
