"""Mixup (eq. 6) and inverse-Mixup (eq. 7-10, Proposition 1).

Mixup at a device:        s_hat = lam * s_i + (1 - lam) * s_j
Inverse-Mixup at server:  s_tilde_n = sum_d lam_hat[n, d] * s_hat_d
where lam_hat = inv(circulant(lams)) (Prop. 1).  For N = 2 and the target
hard label on the lam-class:  lam_hat = lam / (2*lam - 1)  (an
*extrapolation* — the ratios are negative for lam < 0.5, which is exactly
how unmixing works without ever reconstructing a raw sample).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.mixup_kernel import mixup_pallas


# ---------------------------------------------------------------------------
# Proposition 1
# ---------------------------------------------------------------------------

def circulant(lams):
    """Rows are cyclic shifts of (lam_1 .. lam_N) per eq. (8)."""
    lams = jnp.asarray(lams, jnp.float32)
    n = lams.shape[0]
    idx = (jnp.arange(n)[:, None] + jnp.arange(n)[None, :]) % n
    return lams[idx]


def inverse_mixup_ratios(lams):
    """(N,) mixing ratios -> (N, N) inverse ratios; row n yields the sample
    whose hard label is the n-th constituent's label."""
    return jnp.linalg.inv(circulant(lams))


# ---------------------------------------------------------------------------
# Device-side Mixup (eq. 6)
# ---------------------------------------------------------------------------

def mixup_pairs(key, labels, n_pairs: int, num_classes: int):
    """Sample ``n_pairs`` index pairs (i, j) with different labels.

    Rejection-free: draw i uniformly, then draw j uniformly among samples of
    a uniformly-drawn *other* class.  Returns (idx_i, idx_j): (n_pairs,).
    """
    n = labels.shape[0]
    k1, k2, k3 = jax.random.split(key, 3)
    idx_i = jax.random.randint(k1, (n_pairs,), 0, n)
    li = labels[idx_i]
    # draw a different class uniformly
    shift = jax.random.randint(k2, (n_pairs,), 1, num_classes)
    lj = (li + shift) % num_classes
    # pick a uniform sample of class lj via gumbel-max over the class mask
    g = jax.random.gumbel(k3, (n_pairs, n))
    mask = labels[None, :] == lj[:, None]
    idx_j = jnp.argmax(jnp.where(mask, g, -jnp.inf), axis=1)
    return idx_i, idx_j


def make_mixup_batch(x, y, idx_i, idx_j, lam: float, num_classes: int):
    """eq. (6): mixed samples + soft labels + (minor, major) class metadata."""
    xi, xj = x[idx_i], x[idx_j]
    mixed = lam * xi + (1.0 - lam) * xj
    yi = jax.nn.one_hot(y[idx_i], num_classes)
    yj = jax.nn.one_hot(y[idx_j], num_classes)
    soft = lam * yi + (1.0 - lam) * yj
    return mixed, soft, (y[idx_i], y[idx_j])  # minor (lam) / major (1-lam)


def make_mixup_batch_pallas(dev_x, dev_y, idx_i, idx_j, lam: float,
                            num_classes: int):
    """Device-axis-batched eq. (6) through the ``mixup_pallas`` kernel.

    dev_x: (D, n_local, ...); dev_y: (D, n_local); idx_i/idx_j: (D, Ns).
    All D * Ns sample mixes run as one flattened (rows x features) kernel
    call instead of a vmapped jnp lerp; tiny label mixes stay in jnp.
    Returns the same (mixed, soft, (minor, major)) triple — each (D, Ns,
    ...) — as ``jax.vmap(make_mixup_batch)``, which is its parity oracle.
    """
    gather = jax.vmap(lambda x, i: x[i])
    xi = gather(dev_x, idx_i)                      # (D, Ns, ...)
    xj = gather(dev_x, idx_j)
    d, ns = idx_i.shape
    la = jnp.full((d * ns,), lam, jnp.float32)
    mixed = mixup_pallas(xi.reshape(d * ns, -1), xj.reshape(d * ns, -1),
                         la, 1.0 - la).reshape(xi.shape)
    minor = jnp.take_along_axis(dev_y, idx_i, axis=1)
    major = jnp.take_along_axis(dev_y, idx_j, axis=1)
    soft = (lam * jax.nn.one_hot(minor, num_classes) +
            (1.0 - lam) * jax.nn.one_hot(major, num_classes))
    return mixed, soft, (minor, major)


# ---------------------------------------------------------------------------
# Server-side pairing + inverse-Mixup (eq. 7)
# ---------------------------------------------------------------------------

def pair_symmetric(minor, major, device_ids):
    """Vectorized pairing of mixed samples with *symmetric* labels from
    *different* devices: (a, b) pairs with (b, a), d != d'.

    Sort-based over the whole upload set (no per-sample Python loop):
    uploads are keyed by their unordered label pair, split by orientation
    (a < b vs a > b), and rank-aligned within each key group.  Sorting the
    forward side by device ascending and the reverse side descending
    minimises same-device alignments; the (typically few) leftovers —
    rank misalignments and same-device drops — are re-matched by a small
    greedy repair pass, so the result is maximal in the same sense as a
    plain greedy matcher.  Returns an (M, 2) int array of index pairs.
    """
    import numpy as np

    minor = np.asarray(minor)
    major = np.asarray(major)
    device_ids = np.asarray(device_ids, np.int64)  # signed: `-dev` sort key
    n = minor.shape[0]
    empty = np.zeros((0, 2), np.int64)
    if n == 0:
        return empty
    valid = minor != major
    lo = np.minimum(minor, major)
    hi = np.maximum(minor, major)
    base = int(hi.max()) + 1 if n else 1
    key = lo.astype(np.int64) * base + hi
    idx = np.arange(n)
    f = idx[valid & (minor < major)]
    r = idx[valid & (minor > major)]
    if f.size == 0 or r.size == 0:
        return empty
    f = f[np.lexsort((device_ids[f], key[f]))]
    r = r[np.lexsort((-device_ids[r], key[r]))]

    def _ranks(order):  # position within each run of equal keys
        k = key[order]
        starts = np.flatnonzero(np.r_[True, k[1:] != k[:-1]])
        return np.arange(k.size) - np.repeat(
            starts, np.diff(np.r_[starts, k.size]))

    rmax = n + 1
    code_f = key[f] * rmax + _ranks(f)
    code_r = key[r] * rmax + _ranks(r)   # sorted by construction
    pos = np.searchsorted(code_r, code_f)
    pos_c = np.minimum(pos, code_r.size - 1)
    hit = (pos < code_r.size) & (code_r[pos_c] == code_f)
    i, j = f[hit], r[pos_c[hit]]
    keep = device_ids[i] != device_ids[j]
    i, j = i[keep], j[keep]

    # greedy repair over the leftovers (small: only misaligned ranks and
    # same-device drops survive the bulk pass)
    used = np.zeros(n, bool)
    used[i] = True
    used[j] = True
    by_key: dict[int, list[int]] = {}
    for b in r:
        if not used[b]:
            by_key.setdefault(int(key[b]), []).append(b)
    extra_i, extra_j = [], []
    for a in f:
        if used[a]:
            continue
        lst = by_key.get(int(key[a]))
        if not lst:
            continue
        for t, b in enumerate(lst):
            if device_ids[a] != device_ids[b]:
                extra_i.append(a)
                extra_j.append(b)
                lst.pop(t)
                break
    i = np.concatenate([i, np.asarray(extra_i, np.int64)])
    j = np.concatenate([j, np.asarray(extra_j, np.int64)])
    return np.stack([i, j], axis=1)


def inverse_mixup(mixed_a, mixed_b, lam: float):
    """eq. (7) for N=2 on a symmetric pair: returns the two inversely
    mixed-up samples (hard label = lam-class of a, resp. of b)."""
    lam_hat = lam / (2.0 * lam - 1.0)
    s1 = lam_hat * mixed_a + (1.0 - lam_hat) * mixed_b
    s2 = (1.0 - lam_hat) * mixed_a + lam_hat * mixed_b
    return s1, s2


def cycle_lams(n: int, lam: float):
    """Ratio vector (lam, 1-lam, 0, ..., 0) of length ``n``: the cyclic
    lam-order of a length-``n`` label cycle, where member k mixes its own
    class (weight lam) with the next member's class (weight 1-lam).  A
    symmetric pair is exactly the n = 2 case.  ``circulant(cycle_lams(n))``
    is invertible for every n whenever lam != 0.5 (its eigenvalues are
    lam + (1-lam) * omega^k, |lam/(1-lam)| != 1)."""
    v = jnp.zeros((n,), jnp.float32)
    return v.at[0].set(lam).at[1].set(1.0 - lam)


def find_label_cycles(minor, major, device_ids, length: int,
                      max_steps: int = 200_000):
    """Disjoint label cycles of the given length among uploaded mixed
    samples: sequences (e_1 .. e_n) with major[e_k] == minor[e_{k+1}]
    (cyclically) and adjacent members from different devices.

    Host-side greedy DFS on the minor->major label multigraph; runs once
    per training job per cycle length.  The search is bounded by
    ``max_steps`` node expansions in total — a label graph whose chains
    never close (worst case for DFS) exhausts the budget and returns
    whatever was found instead of blowing up exponentially; callers
    degrade gracefully (fewer augmentation samples).  Returns a
    (G, length) int array (rows are disjoint within one call; different
    lengths may reuse uploads — they produce distinct inverse samples).
    """
    import numpy as np

    minor = np.asarray(minor)
    major = np.asarray(major)
    device_ids = np.asarray(device_ids)
    n = minor.shape[0]
    succ: dict[int, list[int]] = {}
    for i in range(n):
        succ.setdefault(int(minor[i]), []).append(i)
    used: set[int] = set()
    cycles: list[list[int]] = []
    budget = [max_steps]

    def _extend(path: list[int]) -> bool:
        if len(path) == length:
            return device_ids[path[-1]] != device_ids[path[0]]
        closing = len(path) == length - 1
        for cand in succ.get(int(major[path[-1]]), ()):
            if budget[0] <= 0:
                return False
            budget[0] -= 1
            if cand in used or cand in path:
                continue
            if device_ids[cand] == device_ids[path[-1]]:
                continue
            # the last member must close the label cycle back to the start
            if closing and int(major[cand]) != int(minor[path[0]]):
                continue
            path.append(cand)
            if _extend(path):
                return True
            path.pop()
        return False

    for start in range(n):
        if budget[0] <= 0:
            break
        if start in used or minor[start] == major[start]:
            continue
        path = [start]
        if _extend(path):
            used.update(path)
            cycles.append(path)
    if not cycles:
        return np.zeros((0, length), np.int64)
    return np.asarray(cycles, np.int64)


def inverse_mixup_cycles(mixed, cycles, lam: float):
    """Batched general-N inverse-Mixup (Prop. 1) over label cycles.

    mixed: (M, F) uploaded mixed samples (flattened features); cycles:
    (G, N) index rows from :func:`find_label_cycles`.  Member k of a cycle
    is lam * x_k + (1-lam) * x_{k+1 (mod N)} in class space, so the stack
    reordered by (N-k) mod N equals circulant(cycle_lams(N, lam)) @ x and
    one (N, N) @ (G, N, F) contraction recovers all G*N hard-label
    samples at once.  Returns (G*N, F); labels are minor[cycles].ravel().
    """
    import numpy as np

    cycles = np.asarray(cycles)
    g, n = cycles.shape
    ratios = inverse_mixup_ratios(cycle_lams(n, lam))      # (N, N)
    perm = (n - np.arange(n)) % n
    stack = jnp.asarray(mixed)[cycles[:, perm]]            # (G, N, F)
    out = jnp.einsum("nk,gkf->gnf", ratios, stack)
    return out.reshape(g * n, -1)


def inverse_mixup_n(mixed_stack, lams):
    """General-N inverse-Mixup: mixed_stack (N, ...) built with cyclic ratio
    shifts (row d of circulant(lams)).  Returns (N, ...) hard-label samples
    via Prop. 1."""
    ratios = inverse_mixup_ratios(lams)  # (N, N)
    flat = mixed_stack.reshape(mixed_stack.shape[0], -1)
    out = ratios @ flat
    return out.reshape(mixed_stack.shape)
