"""Mixup (eq. 6) and inverse-Mixup (eq. 7-10, Proposition 1).

Mixup at a device:        s_hat = lam * s_i + (1 - lam) * s_j
Inverse-Mixup at server:  s_tilde_n = sum_d lam_hat[n, d] * s_hat_d
where lam_hat = inv(circulant(lams)) (Prop. 1).  For N = 2 and the target
hard label on the lam-class:  lam_hat = lam / (2*lam - 1)  (an
*extrapolation* — the ratios are negative for lam < 0.5, which is exactly
how unmixing works without ever reconstructing a raw sample).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Proposition 1
# ---------------------------------------------------------------------------

def circulant(lams):
    """Rows are cyclic shifts of (lam_1 .. lam_N) per eq. (8)."""
    lams = jnp.asarray(lams, jnp.float32)
    n = lams.shape[0]
    idx = (jnp.arange(n)[:, None] + jnp.arange(n)[None, :]) % n
    return lams[idx]


def inverse_mixup_ratios(lams):
    """(N,) mixing ratios -> (N, N) inverse ratios; row n yields the sample
    whose hard label is the n-th constituent's label."""
    return jnp.linalg.inv(circulant(lams))


# ---------------------------------------------------------------------------
# Device-side Mixup (eq. 6)
# ---------------------------------------------------------------------------

def mixup_pairs(key, labels, n_pairs: int, num_classes: int):
    """Sample ``n_pairs`` index pairs (i, j) with different labels.

    Rejection-free: draw i uniformly, then draw j uniformly among samples of
    a uniformly-drawn *other* class.  Returns (idx_i, idx_j): (n_pairs,).
    """
    n = labels.shape[0]
    k1, k2, k3 = jax.random.split(key, 3)
    idx_i = jax.random.randint(k1, (n_pairs,), 0, n)
    li = labels[idx_i]
    # draw a different class uniformly
    shift = jax.random.randint(k2, (n_pairs,), 1, num_classes)
    lj = (li + shift) % num_classes
    # pick a uniform sample of class lj via gumbel-max over the class mask
    g = jax.random.gumbel(k3, (n_pairs, n))
    mask = labels[None, :] == lj[:, None]
    idx_j = jnp.argmax(jnp.where(mask, g, -jnp.inf), axis=1)
    return idx_i, idx_j


def make_mixup_batch(x, y, idx_i, idx_j, lam: float, num_classes: int):
    """eq. (6): mixed samples + soft labels + (minor, major) class metadata."""
    xi, xj = x[idx_i], x[idx_j]
    mixed = lam * xi + (1.0 - lam) * xj
    yi = jax.nn.one_hot(y[idx_i], num_classes)
    yj = jax.nn.one_hot(y[idx_j], num_classes)
    soft = lam * yi + (1.0 - lam) * yj
    return mixed, soft, (y[idx_i], y[idx_j])  # minor (lam) / major (1-lam)


# ---------------------------------------------------------------------------
# Server-side pairing + inverse-Mixup (eq. 7)
# ---------------------------------------------------------------------------

def pair_symmetric(minor, major, device_ids):
    """Greedy pairing of mixed samples with *symmetric* labels from
    *different* devices: (a, b) pairs with (b, a), d != d'.

    Pure-numpy helper (host-side, runs once per training job on the
    collected seed set).  Returns a list of (idx1, idx2).
    """
    import numpy as np

    minor = np.asarray(minor)
    major = np.asarray(major)
    device_ids = np.asarray(device_ids)
    by_pair: dict[tuple[int, int], list[int]] = {}
    for idx, (a, b) in enumerate(zip(minor.tolist(), major.tolist())):
        by_pair.setdefault((a, b), []).append(idx)
    pairs = []
    used = set()
    for (a, b), lst in by_pair.items():
        partners = by_pair.get((b, a), [])
        for i in lst:
            if i in used:
                continue
            for j in partners:
                if j in used or j == i or device_ids[j] == device_ids[i]:
                    continue
                pairs.append((i, j))
                used.add(i)
                used.add(j)
                break
    return pairs


def inverse_mixup(mixed_a, mixed_b, lam: float):
    """eq. (7) for N=2 on a symmetric pair: returns the two inversely
    mixed-up samples (hard label = lam-class of a, resp. of b)."""
    lam_hat = lam / (2.0 * lam - 1.0)
    s1 = lam_hat * mixed_a + (1.0 - lam_hat) * mixed_b
    s2 = (1.0 - lam_hat) * mixed_a + lam_hat * mixed_b
    return s1, s2


def inverse_mixup_n(mixed_stack, lams):
    """General-N inverse-Mixup: mixed_stack (N, ...) built with cyclic ratio
    shifts (row d of circulant(lams)).  Returns (N, ...) hard-label samples
    via Prop. 1."""
    ratios = inverse_mixup_ratios(lams)  # (N, N)
    flat = mixed_stack.reshape(mixed_stack.shape[0], -1)
    out = ratios @ flat
    return out.reshape(mixed_stack.shape)
