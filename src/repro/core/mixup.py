"""Mixup (eq. 6) and inverse-Mixup (eq. 7-10, Proposition 1).

Mixup at a device:        s_hat = lam * s_i + (1 - lam) * s_j
Inverse-Mixup at server:  s_tilde_n = sum_d lam_hat[n, d] * s_hat_d
where lam_hat = inv(circulant(lams)) (Prop. 1).  For N = 2 and the target
hard label on the lam-class:  lam_hat = lam / (2*lam - 1)  (an
*extrapolation* — the ratios are negative for lam < 0.5, which is exactly
how unmixing works without ever reconstructing a raw sample).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.mixup_kernel import mixup_pallas


# ---------------------------------------------------------------------------
# Proposition 1
# ---------------------------------------------------------------------------

def circulant(lams):
    """Rows are cyclic shifts of (lam_1 .. lam_N) per eq. (8)."""
    lams = jnp.asarray(lams, jnp.float32)
    n = lams.shape[0]
    idx = (jnp.arange(n)[:, None] + jnp.arange(n)[None, :]) % n
    return lams[idx]


def inverse_mixup_ratios(lams):
    """(N,) mixing ratios -> (N, N) inverse ratios; row n yields the sample
    whose hard label is the n-th constituent's label."""
    return jnp.linalg.inv(circulant(lams))


# ---------------------------------------------------------------------------
# Device-side Mixup (eq. 6)
# ---------------------------------------------------------------------------

def mixup_pairs(key, labels, n_pairs: int, num_classes: int):
    """Sample ``n_pairs`` index pairs (i, j) with different labels.

    Rejection-free: draw i uniformly, then draw j uniformly among samples of
    a uniformly-drawn *other* class.  Returns (idx_i, idx_j): (n_pairs,).
    """
    n = labels.shape[0]
    k1, k2, k3 = jax.random.split(key, 3)
    idx_i = jax.random.randint(k1, (n_pairs,), 0, n)
    li = labels[idx_i]
    # draw a different class uniformly
    shift = jax.random.randint(k2, (n_pairs,), 1, num_classes)
    lj = (li + shift) % num_classes
    # pick a uniform sample of class lj via gumbel-max over the class mask
    g = jax.random.gumbel(k3, (n_pairs, n))
    mask = labels[None, :] == lj[:, None]
    idx_j = jnp.argmax(jnp.where(mask, g, -jnp.inf), axis=1)
    return idx_i, idx_j


def make_mixup_batch(x, y, idx_i, idx_j, lam: float, num_classes: int):
    """eq. (6): mixed samples + soft labels + (minor, major) class metadata."""
    xi, xj = x[idx_i], x[idx_j]
    mixed = lam * xi + (1.0 - lam) * xj
    yi = jax.nn.one_hot(y[idx_i], num_classes)
    yj = jax.nn.one_hot(y[idx_j], num_classes)
    soft = lam * yi + (1.0 - lam) * yj
    return mixed, soft, (y[idx_i], y[idx_j])  # minor (lam) / major (1-lam)


def make_mixup_batch_pallas(dev_x, dev_y, idx_i, idx_j, lam: float,
                            num_classes: int):
    """Device-axis-batched eq. (6) through the ``mixup_pallas`` kernel.

    dev_x: (D, n_local, ...); dev_y: (D, n_local); idx_i/idx_j: (D, Ns).
    All D * Ns sample mixes run as one flattened (rows x features) kernel
    call instead of a vmapped jnp lerp; tiny label mixes stay in jnp.
    Returns the same (mixed, soft, (minor, major)) triple — each (D, Ns,
    ...) — as ``jax.vmap(make_mixup_batch)``, which is its parity oracle.
    """
    gather = jax.vmap(lambda x, i: x[i])
    xi = gather(dev_x, idx_i)                      # (D, Ns, ...)
    xj = gather(dev_x, idx_j)
    d, ns = idx_i.shape
    la = jnp.full((d * ns,), lam, jnp.float32)
    mixed = mixup_pallas(xi.reshape(d * ns, -1), xj.reshape(d * ns, -1),
                         la, 1.0 - la).reshape(xi.shape)
    minor = jnp.take_along_axis(dev_y, idx_i, axis=1)
    major = jnp.take_along_axis(dev_y, idx_j, axis=1)
    soft = (lam * jax.nn.one_hot(minor, num_classes) +
            (1.0 - lam) * jax.nn.one_hot(major, num_classes))
    return mixed, soft, (minor, major)


# ---------------------------------------------------------------------------
# Server-side pairing + inverse-Mixup (eq. 7)
# ---------------------------------------------------------------------------

def pair_symmetric(minor, major, device_ids):
    """Vectorized pairing of mixed samples with *symmetric* labels from
    *different* devices: (a, b) pairs with (b, a), d != d'.

    Sort-based over the whole upload set (no per-sample Python loop):
    uploads are keyed by their unordered label pair, split by orientation
    (a < b vs a > b), and rank-aligned within each key group.  Sorting the
    forward side by device ascending and the reverse side descending
    minimises same-device alignments; the (typically few) leftovers —
    rank misalignments and same-device drops — are re-matched by a small
    greedy repair pass, and an augmenting swap pass absorbs same-device
    leftovers through already-matched pairs (leftovers of one key all
    share a device; a matched pair of that key whose members both avoid
    it can be rewired to take one leftover in), so the yield never falls
    below a plain greedy matcher's.  Returns an (M, 2) int array of
    index pairs.
    """
    import numpy as np

    minor = np.asarray(minor)
    major = np.asarray(major)
    device_ids = np.asarray(device_ids, np.int64)  # signed: `-dev` sort key
    n = minor.shape[0]
    empty = np.zeros((0, 2), np.int64)
    if n == 0:
        return empty
    valid = minor != major
    lo = np.minimum(minor, major)
    hi = np.maximum(minor, major)
    base = int(hi.max()) + 1 if n else 1
    key = lo.astype(np.int64) * base + hi
    idx = np.arange(n)
    f = idx[valid & (minor < major)]
    r = idx[valid & (minor > major)]
    if f.size == 0 or r.size == 0:
        return empty
    f = f[np.lexsort((device_ids[f], key[f]))]
    r = r[np.lexsort((-device_ids[r], key[r]))]

    def _ranks(order):  # position within each run of equal keys
        k = key[order]
        starts = np.flatnonzero(np.r_[True, k[1:] != k[:-1]])
        return np.arange(k.size) - np.repeat(
            starts, np.diff(np.r_[starts, k.size]))

    rmax = n + 1
    code_f = key[f] * rmax + _ranks(f)
    code_r = key[r] * rmax + _ranks(r)   # sorted by construction
    pos = np.searchsorted(code_r, code_f)
    pos_c = np.minimum(pos, code_r.size - 1)
    hit = (pos < code_r.size) & (code_r[pos_c] == code_f)
    i, j = f[hit], r[pos_c[hit]]
    keep = device_ids[i] != device_ids[j]
    i, j = i[keep], j[keep]

    # greedy repair over the leftovers (small: only misaligned ranks and
    # same-device drops survive the bulk pass)
    used = np.zeros(n, bool)
    used[i] = True
    used[j] = True
    by_key: dict[int, list[int]] = {}
    for b in r:
        if not used[b]:
            by_key.setdefault(int(key[b]), []).append(b)
    extra_i, extra_j = [], []
    for a in f:
        if used[a]:
            continue
        lst = by_key.get(int(key[a]))
        if not lst:
            continue
        for t, b in enumerate(lst):
            if device_ids[a] != device_ids[b]:
                extra_i.append(a)
                extra_j.append(b)
                lst.pop(t)
                break
    i = list(np.concatenate([i, np.asarray(extra_i, np.int64)]))
    j = list(np.concatenate([j, np.asarray(extra_j, np.int64)]))

    # augmenting swap pass: leftovers that survive the repair all share
    # one device per key (a cross-device leftover pair would have been
    # repaired), so a matched pair (i_t, j_t) of the same key with both
    # members off that device absorbs one leftover (a, b): rewire to
    # (a, j_t) and add (i_t, b).  Longer augmenting chains cannot help —
    # any pair already touching the leftover device blocks on it again.
    used[i] = True
    used[j] = True
    left_f: dict[int, list[int]] = {}
    left_r: dict[int, list[int]] = {}
    for a in f:
        if not used[a]:
            left_f.setdefault(int(key[a]), []).append(a)
    for b in r:
        if not used[b]:
            left_r.setdefault(int(key[b]), []).append(b)
    if left_f and left_r:
        pairs_of: dict[int, list[int]] = {}
        for t in range(len(i)):
            pairs_of.setdefault(int(key[i[t]]), []).append(t)
        for k_, fa in left_f.items():
            rb = left_r.get(k_)
            if not rb:
                continue
            ts = pairs_of.get(k_, [])
            for a, b in zip(fa, rb):
                if device_ids[a] != device_ids[b]:  # unreachable after
                    i.append(a)                     # repair; kept as a
                    j.append(b)                     # safety net
                    continue
                d = device_ids[a]
                for pos, t in enumerate(ts):
                    if device_ids[i[t]] != d and device_ids[j[t]] != d:
                        i.append(i[t])
                        j.append(b)
                        i[t] = a        # pair t becomes (a, j_t)
                        ts.pop(pos)     # its forward now sits on d
                        break
    return np.stack([np.asarray(i, np.int64),
                     np.asarray(j, np.int64)], axis=1)


def inverse_mixup(mixed_a, mixed_b, lam: float):
    """eq. (7) for N=2 on a symmetric pair: returns the two inversely
    mixed-up samples (hard label = lam-class of a, resp. of b)."""
    lam_hat = lam / (2.0 * lam - 1.0)
    s1 = lam_hat * mixed_a + (1.0 - lam_hat) * mixed_b
    s2 = (1.0 - lam_hat) * mixed_a + lam_hat * mixed_b
    return s1, s2


def cycle_lams(n: int, lam: float):
    """Ratio vector (lam, 1-lam, 0, ..., 0) of length ``n``: the cyclic
    lam-order of a length-``n`` label cycle, where member k mixes its own
    class (weight lam) with the next member's class (weight 1-lam).  A
    symmetric pair is exactly the n = 2 case.  ``circulant(cycle_lams(n))``
    is invertible for every n whenever lam != 0.5 (its eigenvalues are
    lam + (1-lam) * omega^k, |lam/(1-lam)| != 1)."""
    v = jnp.zeros((n,), jnp.float32)
    return v.at[0].set(lam).at[1].set(1.0 - lam)


def find_label_cycles_dfs(minor, major, device_ids, length: int,
                          max_steps: int = 200_000):
    """Reference (small-n) cycle search: disjoint label cycles of the
    given length among uploaded mixed samples — sequences (e_1 .. e_n)
    with major[e_k] == minor[e_{k+1}] (cyclically) and adjacent members
    from different devices.

    Host-side greedy DFS on the minor->major label multigraph, bounded by
    ``max_steps`` node expansions in total — a label graph whose chains
    never close (worst case for DFS) exhausts the budget and returns
    whatever was found instead of blowing up exponentially; callers
    degrade gracefully (fewer augmentation samples).  Kept as the parity
    oracle for :func:`find_label_cycles_segment`, which has no budget and
    is the production path.  Returns a (G, length) int array (rows are
    disjoint within one call; different lengths may reuse uploads — they
    produce distinct inverse samples).
    """
    import numpy as np

    minor = np.asarray(minor)
    major = np.asarray(major)
    device_ids = np.asarray(device_ids)
    n = minor.shape[0]
    succ: dict[int, list[int]] = {}
    for i in range(n):
        # degenerate uploads (minor == major) would yield single-class
        # "inverse" samples; keep them out of cycle membership entirely,
        # not just out of the start set
        if minor[i] == major[i]:
            continue
        succ.setdefault(int(minor[i]), []).append(i)
    used: set[int] = set()
    cycles: list[list[int]] = []
    budget = [max_steps]

    def _extend(path: list[int]) -> bool:
        if len(path) == length:
            return device_ids[path[-1]] != device_ids[path[0]]
        closing = len(path) == length - 1
        for cand in succ.get(int(major[path[-1]]), ()):
            if budget[0] <= 0:
                return False
            budget[0] -= 1
            if cand in used or cand in path:
                continue
            if device_ids[cand] == device_ids[path[-1]]:
                continue
            # the last member must close the label cycle back to the start
            if closing and int(major[cand]) != int(minor[path[0]]):
                continue
            path.append(cand)
            if _extend(path):
                return True
            path.pop()
        return False

    for start in range(n):
        if budget[0] <= 0:
            break
        if start in used or minor[start] == major[start]:
            continue
        path = [start]
        if _extend(path):
            used.update(path)
            cycles.append(path)
    if not cycles:
        return np.zeros((0, length), np.int64)
    return np.asarray(cycles, np.int64)


def _cycle_successors(minor, major, device_ids, alive, sweep: int,
                      stream: int):
    """One injective partial successor map over the ``alive`` edge subset
    of the minor->major label multigraph.

    Edges needing a successor are sorted by major label and candidate
    successors by minor label; within each label segment the two sides
    are rank-aligned.  The first sweep of stream 0 anti-aligns devices
    (pred side device-ascending, succ side device-descending — the
    ``pair_symmetric`` trick) to minimise same-device alignments; later
    sweeps shuffle within segments with a deterministic per-(stream,
    sweep) RNG so repeat passes explore different matchings.  Same-device
    alignments are dropped — the reshuffled sweeps recover them.  Returns
    succ: (n,) int64 with -1 for edges without a successor; distinct
    ranks within a segment make the map injective, so the successor
    graph is simple paths + simple cycles (no rho shapes).
    """
    import numpy as np

    if sweep == 0 and stream == 0:
        p = alive[np.lexsort((device_ids[alive], major[alive]))]
        s = alive[np.lexsort((-device_ids[alive], minor[alive]))]
    else:
        rng = np.random.default_rng((stream << 20) + sweep)
        p = alive[np.lexsort((rng.random(alive.size), major[alive]))]
        s = alive[np.lexsort((rng.random(alive.size), minor[alive]))]
    n_labels = int(max(minor[alive].max(), major[alive].max())) + 1
    cnt_p = np.bincount(major[p], minlength=n_labels)
    cnt_s = np.bincount(minor[s], minlength=n_labels)
    start_p = np.concatenate(([0], np.cumsum(cnt_p)[:-1]))
    start_s = np.concatenate(([0], np.cumsum(cnt_s)[:-1]))
    rank_p = np.arange(p.size) - start_p[major[p]]
    size_s = cnt_s[major[p]]
    has = rank_p < size_s          # demand beyond the supply gets nothing
    src = p[has]
    cand = s[start_s[major[src]] + rank_p[has]]
    ok = device_ids[src] != device_ids[cand]
    succ = np.full(minor.shape[0], -1, np.int64)
    succ[src[ok]] = cand[ok]
    return succ


def _extract_cycle_windows(succ, minor, major, device_ids, length: int):
    """Disjoint length-``length`` label cycles from one successor map.

    Walks ``length - 1`` pointer steps from every edge (the successor
    graph is injective, so trails never merge); a window
    [i, succ(i), ..., succ^{L-1}(i)] is a valid cycle iff it is revisit-
    free and closes label- and device-wise (major of the last == minor of
    the first, different devices).  Overlapping windows are resolved by
    claim rounds: every surviving start scatter-claims its members with
    min-index priority and keeps the window only if it won all of them —
    the globally minimal start always wins, so each round makes progress.
    Returns (W, length) rows.
    """
    import numpy as np

    n = succ.shape[0]
    succ_ext = np.concatenate((succ, [-1]))        # index -1 stays -1
    trail = np.empty((length, n), np.int64)
    trail[0] = np.arange(n)
    for k in range(1, length):
        trail[k] = succ_ext[trail[k - 1]]
    last = trail[length - 1]
    ok = last >= 0
    # injective map => a revisit implies a sub-cycle through the start,
    # so "no member equals the start" is exactly pairwise distinctness
    ok &= np.all(trail[1:] != trail[0], axis=0)
    safe = np.maximum(last, 0)
    ok &= major[safe] == minor[trail[0]]
    ok &= device_ids[safe] != device_ids[trail[0]]
    starts = np.flatnonzero(ok)

    rows = []
    used = np.zeros(n, bool)
    while starts.size:
        members = trail[:, starts]                 # (L, S)
        claim = np.full(n, n, np.int64)
        np.minimum.at(claim, members.ravel(),
                      np.broadcast_to(starts, members.shape).ravel())
        win = np.all(claim[members] == starts[None, :], axis=0)
        won = trail[:, starts[win]]
        rows.append(won.T)
        used[won.ravel()] = True
        starts = starts[~win]
        starts = starts[~np.any(used[trail[:, starts]], axis=0)]
    if not rows:
        return np.zeros((0, length), np.int64)
    return np.concatenate(rows, axis=0)


def _segment_stream(minor, major, device_ids, length: int, stream: int,
                    miss_budget: int, polish_cap: int):
    """One best-effort cycle packing: matching sweeps until ``miss_budget``
    consecutive empty sweeps, then a DFS polish over the (small, capped)
    leftover edge set that re-matching no longer reaches."""
    import numpy as np

    alive_mask = minor != major    # degenerate edges never join cycles
    rows_all = []
    sweep = misses = 0
    while True:
        alive = np.flatnonzero(alive_mask)
        if alive.size < length:
            break
        succ = _cycle_successors(minor, major, device_ids, alive, sweep,
                                 stream)
        rows = _extract_cycle_windows(succ, minor, major, device_ids,
                                      length)
        sweep += 1
        if rows.size == 0:
            misses += 1
            if misses >= miss_budget:
                break
            continue
        misses = 0
        rows_all.append(rows)
        alive_mask[rows.ravel()] = False
    left = np.flatnonzero(alive_mask)
    if length <= left.size <= polish_cap:
        sub = find_label_cycles_dfs(minor[left], major[left],
                                    device_ids[left], length)
        if len(sub):
            rows_all.append(left[sub])
    if not rows_all:
        return np.zeros((0, length), np.int64)
    return np.concatenate(rows_all, axis=0)


def find_label_cycles_segment(minor, major, device_ids, length: int,
                              miss_budget: int = 12,
                              polish_cap: int = 4096,
                              restarts: int = 6, small_n: int = 2048):
    """Vectorized segment/sort cycle search — the production replacement
    for :func:`find_label_cycles_dfs`, O(n log n) per sweep with no step
    budget, so augmentation no longer degrades beyond ~10^4 uploads.

    Each sweep builds one injective successor matching over the remaining
    edges (:func:`_cycle_successors`), extracts disjoint cycles from its
    pointer trails (:func:`_extract_cycle_windows`), and removes them;
    each sweep reshuffles the segment alignment so near-miss matchings
    (same-device drops, unlucky pairings) get rewired.  A stream stops
    after ``miss_budget`` consecutive empty sweeps and DFS-polishes its
    leftover (at most ``polish_cap`` edges, so the polish cost is
    bounded).  At small n (<= ``small_n``) up to ``restarts``
    deterministic shuffle streams run and the highest-yield packing wins
    — restarts close most of the packing gap to the greedy DFS while
    staying irrelevant (and skipped) at scale.  Degenerate edges with
    minor == major are excluded from membership up front.  Same contract
    as the DFS: (G, length) rows, disjoint within one call.
    """
    import numpy as np

    minor = np.asarray(minor)
    major = np.asarray(major)
    device_ids = np.asarray(device_ids, np.int64)  # signed: `-dev` sort key
    if minor.shape[0] == 0 or length < 2:
        return np.zeros((0, length), np.int64)
    streams = max(1, restarts) if minor.shape[0] <= small_n else 1
    # count upper bound of any packing: a stream that reaches it cannot
    # be beaten, so further restarts are redundant (a later stream only
    # replaces `best` on strictly greater yield — skipping ties is
    # behaviour-identical)
    max_cycles = int(np.count_nonzero(minor != major)) // length
    best = np.zeros((0, length), np.int64)
    for stream in range(streams):
        rows = _segment_stream(minor, major, device_ids, length, stream,
                               miss_budget, polish_cap)
        if len(rows) > len(best):
            best = rows
        if len(best) >= max_cycles:
            break
    return best


def find_label_cycles(minor, major, device_ids, length: int,
                      max_steps: int = 200_000, method: str = "auto",
                      small_n: int = 2048):
    """Disjoint label cycles of the given length among uploaded mixed
    samples (see :func:`find_label_cycles_segment` for the cycle
    contract and :func:`find_label_cycles_dfs` for the reference).

    ``method="auto"`` (default) runs the vectorized segment/sort search,
    and at small n (<= ``small_n``, where the DFS budget cannot bind)
    also runs the DFS oracle and keeps whichever packing yields more
    cycles — ties prefer the DFS for continuity with the pre-vectorized
    behaviour.  ``method="segment"`` is the pure vectorized path;
    ``method="dfs"`` the budgeted greedy reference (``max_steps`` only
    applies to DFS calls)."""
    if method == "dfs":
        return find_label_cycles_dfs(minor, major, device_ids, length,
                                     max_steps)
    if method not in ("segment", "auto"):
        raise ValueError(f"unknown cycle-search method {method!r}; "
                         "use 'auto', 'segment' or 'dfs'")
    import numpy as np

    minor = np.asarray(minor)
    rows = find_label_cycles_segment(minor, major, device_ids, length,
                                     small_n=small_n)
    if method == "auto" and 0 < minor.shape[0] <= small_n:
        # the DFS cannot beat a packing at the count upper bound — only
        # tie it — so skip the second search there
        max_cycles = int(np.count_nonzero(minor != np.asarray(major))
                         ) // length
        if len(rows) < max_cycles:
            ref = find_label_cycles_dfs(minor, major, device_ids, length,
                                        max_steps)
            if len(ref) >= len(rows):
                return ref
    return rows


def inverse_mixup_cycles(mixed, cycles, lam: float):
    """Batched general-N inverse-Mixup (Prop. 1) over label cycles.

    mixed: (M, F) uploaded mixed samples (flattened features); cycles:
    (G, N) index rows from :func:`find_label_cycles`.  Member k of a cycle
    is lam * x_k + (1-lam) * x_{k+1 (mod N)} in class space, so the stack
    reordered by (N-k) mod N equals circulant(cycle_lams(N, lam)) @ x and
    one (N, N) @ (G, N, F) contraction recovers all G*N hard-label
    samples at once.  Returns (G*N, F); labels are minor[cycles].ravel().
    """
    import numpy as np

    cycles = np.asarray(cycles)
    g, n = cycles.shape
    ratios = inverse_mixup_ratios(cycle_lams(n, lam))      # (N, N)
    perm = (n - np.arange(n)) % n
    stack = jnp.asarray(mixed)[cycles[:, perm]]            # (G, N, F)
    out = jnp.einsum("nk,gkf->gnf", ratios, stack)
    return out.reshape(g * n, -1)


def inverse_mixup_n(mixed_stack, lams):
    """General-N inverse-Mixup: mixed_stack (N, ...) built with cyclic ratio
    shifts (row d of circulant(lams)).  Returns (N, ...) hard-label samples
    via Prop. 1."""
    ratios = inverse_mixup_ratios(lams)  # (N, N)
    flat = mixed_stack.reshape(mixed_stack.shape[0], -1)
    out = ratios @ flat
    return out.reshape(mixed_stack.shape)
