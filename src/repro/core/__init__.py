"""Mix2FLD core: the paper's contribution as composable JAX modules."""
from .mixup import (mixup_pairs, inverse_mixup_ratios, inverse_mixup,
                    inverse_mixup_n, make_mixup_batch,
                    make_mixup_batch_pallas, pair_symmetric,
                    cycle_lams, find_label_cycles,
                    inverse_mixup_cycles)  # noqa: F401
from .losses import cross_entropy, kd_regularizer, fd_loss  # noqa: F401
from .outputs import label_averaged_outputs  # noqa: F401
