"""Per-protocol payload accounting (Sec. II-C / III-A).

FL : B_up = B_dn = b_mod * N_mod
FD : B_up = B_dn = b_out * N_L^2
FLD-family: B_up = b_out * N_L^2 (+ b_s * N_s on the first round),
            B_dn = b_mod * N_mod
"""
from __future__ import annotations

B_MOD = 32  # bits per weight
B_OUT = 32  # bits per output element


def payload_bits(protocol: str, *, n_mod: int, n_labels: int,
                 sample_bits: int = 0, n_seed: int = 0,
                 first_round: bool = False) -> tuple[float, float]:
    """Returns (uplink_bits, downlink_bits) per device for one round."""
    out_bits = B_OUT * n_labels * n_labels
    mod_bits = B_MOD * n_mod
    if protocol == "fl":
        return mod_bits, mod_bits
    if protocol == "fd":
        return out_bits, out_bits
    if protocol in ("fld", "mixfld", "mix2fld"):
        up = out_bits + (sample_bits * n_seed if first_round else 0)
        return up, mod_bits
    raise ValueError(protocol)


def round_slot_plan(protocol: str, cfg, *, n_mod: int, n_labels: int,
                    sample_bits: int = 0, n_seed: int = 0) -> dict:
    """Host-side per-round link plan for one (protocol, channel) point.

    Returns the per-slot success probabilities and the decode-slot
    requirements the traced channel draw (``model.round_trip_traced``)
    consumes: ``up_slots_first`` covers the seed-carrying first round of
    the FLD family, ``up_slots`` every later round (identical for FL/FD).
    The sweep engine stacks these over its config grid so batched
    SNR/outage draws stay bitwise-equal to the per-point loop.
    """
    from .model import slots_needed

    p_up, bits_up = cfg.link_budget(True)
    p_dn, bits_dn = cfg.link_budget(False)
    up1, dn1 = payload_bits(protocol, n_mod=n_mod, n_labels=n_labels,
                            sample_bits=sample_bits, n_seed=n_seed,
                            first_round=True)
    up, dn = payload_bits(protocol, n_mod=n_mod, n_labels=n_labels,
                          sample_bits=sample_bits, n_seed=n_seed,
                          first_round=False)
    if dn1 != dn:  # the plan carries ONE dn_slots; a round-dependent
        # downlink payload would silently desync sweeps from the loop path
        raise ValueError(f"round-dependent downlink payload for "
                         f"{protocol!r}: {dn1} vs {dn} bits")
    return {"p_up": p_up, "p_dn": p_dn,
            "up_slots_first": slots_needed(up1, bits_up),
            "up_slots": slots_needed(up, bits_up),
            "dn_slots": slots_needed(dn, bits_dn)}
