"""Per-protocol payload accounting (Sec. II-C / III-A).

FL : B_up = B_dn = b_mod * N_mod
FD : B_up = B_dn = b_out * N_L^2
FLD-family: B_up = b_out * N_L^2 (+ b_s * N_s on the first round),
            B_dn = b_mod * N_mod
"""
from __future__ import annotations

B_MOD = 32  # bits per weight
B_OUT = 32  # bits per output element


def payload_bits(protocol: str, *, n_mod: int, n_labels: int,
                 sample_bits: int = 0, n_seed: int = 0,
                 first_round: bool = False) -> tuple[float, float]:
    """Returns (uplink_bits, downlink_bits) per device for one round."""
    out_bits = B_OUT * n_labels * n_labels
    mod_bits = B_MOD * n_mod
    if protocol == "fl":
        return mod_bits, mod_bits
    if protocol == "fd":
        return out_bits, out_bits
    if protocol in ("fld", "mixfld", "mix2fld"):
        up = out_bits + (sample_bits * n_seed if first_round else 0)
        return up, mod_bits
    raise ValueError(protocol)
