"""Payload codecs + per-protocol payload accounting (Sec. II-C / III-A).

Uncoded payloads:

FL : B_up = B_dn = b_mod * N_mod
FD : B_up = B_dn = b_out * N_L^2
FLD-family: B_up = b_out * N_L^2 (+ b_s * N_s on the first round),
            B_dn = b_mod * N_mod

**Codecs** transform what the uplink actually carries — the link
pipeline (``channel.pipeline``) runs every device->server transfer
through ``encode -> channel -> decode``, and this module is the codec
registry both the traced transforms and the bit accounting read from:

========================  =================================================
``identity``              the raw payload (no-op transform, bitwise
                          transparent — the pre-pipeline behaviour)
``quantize{bits}``        stochastic rounding to ``2^bits - 1`` levels
                          (Sattler et al., *Communication-Efficient
                          Federated Distillation*): uplink element width
                          drops from 32 to ``bits``
``delta``                 soft-label tables delta-coded against the
                          receiver-tracked previous global average (the
                          Sattler delta stage; bit-transparent alone, the
                          substrate quantized/sparse coding plugs into)
``dp_gaussian{sigma}``    clip + Gaussian noise from ``core.privacy``
                          (Hu et al.): a per-round (epsilon, delta) DP
                          release, accounted by ``GaussianAccountant``
========================  =================================================

Codecs apply to the *recurring* uplink payload (soft-label tables for
the FD/FLD family, model parameters for FL); the first-round seed-sample
bits of the FLD family and the downlink model broadcast stay uncoded.
``payload_bits``/``round_slot_plan`` take the codec, so decode-slot
requirements — and therefore simulated channel latency — respond to
compression.

Protocol names are validated through ``repro.registry`` — the single
source of truth shared with ``core.protocols`` and ``sweep.axes``, so
every registered spelling (``"mix2fd"`` included) works here and unknown
names raise the one shared ValueError.
"""
from __future__ import annotations

import dataclasses
import re
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..registry import FLD_FAMILY, canonical_protocol

B_MOD = 32  # bits per weight
B_OUT = 32  # bits per output element

#: Registered codec family names (the structural axis: programs group by
#: (protocol, codec) in the sweep engine; numeric parameters batch).
CODECS = ("identity", "quantize", "delta", "dp_gaussian")

_CODEC_RE = re.compile(r"^(?P<name>[a-z_]+?)(?P<param>\d+(?:\.\d+)?)?$")


@dataclasses.dataclass(frozen=True)
class CodecSpec:
    """One resolved link codec: family name + numeric parameters.

    Built from a config via :func:`parse_codec` — a parameterized spec
    string (``"quantize8"``, ``"dp_gaussian0.5"``) overrides the
    corresponding field default.
    """
    name: str = "identity"
    quant_bits: int = 8
    dp_sigma: float = 1.0
    dp_clip: float = 1.0
    dp_delta: float = 1e-5

    def __post_init__(self):
        if self.name not in CODECS:
            raise ValueError(
                f"unknown codec {self.name!r}; one of {CODECS} "
                f"(parameterized: 'quantize8', 'dp_gaussian0.5')")
        if self.name == "quantize" and not 1 <= self.quant_bits <= 32:
            raise ValueError(
                f"quantize bits must be in [1, 32], got {self.quant_bits}")
        if self.name == "dp_gaussian":
            # validates sigma > 0 and delta in (0, 1) with one message
            from ..core.privacy import gaussian_epsilon
            gaussian_epsilon(self.dp_sigma, self.dp_delta, 1)

    @property
    def levels(self) -> float:
        """Quantization grid resolution (``2^bits - 1`` steps on [0, 1])."""
        return float(2 ** self.quant_bits - 1)

    @property
    def stochastic(self) -> bool:
        """True iff encoding consumes PRNG randomness (the pipeline only
        folds a codec key into the round stream for these, keeping
        identity/delta runs on the pre-pipeline PRNG schedule)."""
        return self.name in ("quantize", "dp_gaussian")

    def element_bits(self, base_bits: int) -> int:
        """Bit width of one encoded payload element (``base_bits`` is
        the uncoded width: B_OUT for output tables, B_MOD for weights)."""
        return self.quant_bits if self.name == "quantize" else base_bits


def parse_codec(spec, *, quant_bits: int = 8, dp_sigma: float = 1.0,
                dp_clip: float = 1.0, dp_delta: float = 1e-5) -> CodecSpec:
    """Resolve a codec spec — a :class:`CodecSpec` (passed through), a
    family name (``"quantize"``), or a parameterized string
    (``"quantize8"``, ``"dp_gaussian0.5"``) whose suffix overrides the
    keyword default for that family."""
    if isinstance(spec, CodecSpec):
        return spec
    m = _CODEC_RE.match(str(spec))
    name = m.group("name") if m else str(spec)
    param = m.group("param") if m else None
    if name not in CODECS:
        # surface the shared message (includes the parameterized forms)
        return CodecSpec(name=str(spec))
    if param is not None:
        if name == "quantize":
            quant_bits = int(param)
        elif name == "dp_gaussian":
            dp_sigma = float(param)
        else:
            raise ValueError(
                f"codec {name!r} takes no numeric parameter "
                f"(got {spec!r})")
    return CodecSpec(name=name, quant_bits=quant_bits, dp_sigma=dp_sigma,
                     dp_clip=dp_clip, dp_delta=dp_delta)


@dataclasses.dataclass(frozen=True)
class LinkConfig:
    """The link-codec half of a federated config, as a typed sub-config.

    ``FederatedConfig.channel`` groups what used to be five flat fields
    (``codec``/``quant_bits``/``dp_sigma``/``dp_clip``/``dp_delta``);
    validation happens once here through :func:`parse_codec` (a bad spec
    raises at construction, not first use).  Distinct from
    ``repro.channel.ChannelConfig`` — that is the *physical* channel
    (SNR, slots); this is what the payload carries over it."""
    codec: str = "identity"
    quant_bits: int = 8
    dp_sigma: float = 1.0
    dp_clip: float = 1.0
    dp_delta: float = 1e-5

    def __post_init__(self):
        self.spec()  # one validation site: parse eagerly, raise early

    def spec(self) -> CodecSpec:
        """The resolved :class:`CodecSpec` (parameterized strings like
        ``"quantize4"`` override the field defaults)."""
        return parse_codec(self.codec, quant_bits=self.quant_bits,
                           dp_sigma=self.dp_sigma, dp_clip=self.dp_clip,
                           dp_delta=self.dp_delta)


# ---------------------------------------------------------------------------
# Traced codec transforms (the encode/decode halves the pipeline stages
# compose; numeric parameters may be traced per-config scalars)
# ---------------------------------------------------------------------------

def stochastic_round(x, key, levels):
    """Unbiased stochastic rounding of ``x`` in [0, 1] onto a uniform
    grid of ``levels + 1`` points: E[round(x)] = x, |round(x) - x| <=
    1/levels.  ``levels`` may be a traced scalar (a swept bit width)."""
    u = jax.random.uniform(key, x.shape, x.dtype)
    return jnp.clip(jnp.floor(x * levels + u) / levels, 0.0, 1.0)


def quantize_affine(x, key, levels):
    """Stochastic rounding of an arbitrary-range array: affine-rescale to
    [0, 1] by the array's own (min, max) — the two scale floats ride
    along uncoded, a negligible per-leaf overhead — then round."""
    lo, hi = jnp.min(x), jnp.max(x)
    scale = jnp.maximum(hi - lo, 1e-12)
    return lo + stochastic_round((x - lo) / scale, key, levels) * scale


def encode_table(spec_name: str, table, key, ref, levels, dp_sigma,
                 dp_clip):
    """Encode one device's soft-label table (C, C) for the uplink.
    ``ref`` is that device's receiver-tracked previous global average
    (its ``dev_gout`` copy — the server knows it, having observed which
    downlinks decoded).  Identity returns the input unchanged."""
    if spec_name == "identity":
        return table
    if spec_name == "quantize":
        return stochastic_round(table, key, levels)  # tables live in [0,1]
    if spec_name == "delta":
        return table - ref
    if spec_name == "dp_gaussian":
        from ..core.privacy import gaussian_mechanism
        return gaussian_mechanism(table, key, dp_sigma, dp_clip)
    raise ValueError(f"unknown codec {spec_name!r}; one of {CODECS}")


def decode_table(spec_name: str, coded, ref):
    """Receiver half for soft-label tables (delta adds the tracked
    reference back; the lossy codecs decode as-is)."""
    return coded + ref if spec_name == "delta" else coded


def encode_params(spec_name: str, params, key, ref, levels, dp_sigma,
                  dp_clip):
    """Encode one device's model parameters (FL uplink).  ``ref`` is the
    round's starting global model (both ends hold it); per-leaf keys are
    folded from ``key``."""
    if spec_name == "identity":
        return params
    leaves, treedef = jax.tree.flatten(params)
    if spec_name == "quantize":
        out = [quantize_affine(x, jax.random.fold_in(key, i), levels)
               for i, x in enumerate(leaves)]
        return jax.tree.unflatten(treedef, out)
    if spec_name == "delta":
        return jax.tree.map(jnp.subtract, params, ref)
    if spec_name == "dp_gaussian":
        from ..core.privacy import gaussian_mechanism_tree
        return gaussian_mechanism_tree(params, key, dp_sigma, dp_clip)
    raise ValueError(f"unknown codec {spec_name!r}; one of {CODECS}")


def decode_params(spec_name: str, coded, ref):
    if spec_name == "delta":
        return jax.tree.map(jnp.add, coded, ref)
    return coded


# ---------------------------------------------------------------------------
# Codec-aware bit accounting
# ---------------------------------------------------------------------------

class RoundPayload(NamedTuple):
    """Per-device payload bits of one protocol point, with the
    first-round vs steady-state uplink split explicit (the FLD family's
    round-1 seed upload is the whole asymmetry — callers that need both
    must not silently share kwargs between two ``payload_bits`` calls)."""
    up_first: float
    up_steady: float
    dn: float


def round_payload_bits(protocol: str, *, n_mod: int, n_labels: int,
                       sample_bits: int = 0, n_seed: int = 0,
                       codec="identity") -> RoundPayload:
    """Per-device (first-round uplink, steady-state uplink, downlink)
    bits for one (protocol, codec) point."""
    proto = canonical_protocol(protocol)
    spec = parse_codec(codec)
    out_bits = spec.element_bits(B_OUT) * n_labels * n_labels
    mod_bits = B_MOD * n_mod
    if proto == "fl":
        up = spec.element_bits(B_MOD) * n_mod
        return RoundPayload(up, up, mod_bits)
    if proto == "fd":
        # uplink-only codec: the downlink broadcast of G_out stays raw
        return RoundPayload(out_bits, out_bits,
                            B_OUT * n_labels * n_labels)
    assert proto in FLD_FAMILY
    # round-1 seed samples ride along raw (they are the Mixup-privatized
    # samples; the codec covers the recurring soft-label stream)
    return RoundPayload(out_bits + sample_bits * n_seed, out_bits,
                        mod_bits)


def payload_bits(protocol: str, *, n_mod: int, n_labels: int,
                 sample_bits: int = 0, n_seed: int = 0,
                 first_round: bool = False,
                 codec="identity") -> tuple[float, float]:
    """Returns (uplink_bits, downlink_bits) per device for one round."""
    p = round_payload_bits(protocol, n_mod=n_mod, n_labels=n_labels,
                           sample_bits=sample_bits, n_seed=n_seed,
                           codec=codec)
    return (p.up_first if first_round else p.up_steady), p.dn


def round_slot_plan(protocol: str, cfg, *, n_mod: int, n_labels: int,
                    sample_bits: int = 0, n_seed: int = 0,
                    codec="identity") -> dict:
    """Host-side per-round link plan for one (protocol, codec, channel)
    point.

    Returns the per-slot success probabilities and the decode-slot
    requirements the traced channel draw (``model.round_trip_traced``)
    consumes — ``up_slots_first`` covers the seed-carrying first round of
    the FLD family, ``up_slots`` every later round (identical for FL/FD)
    — plus the payload bits they were derived from (``up_bits_first`` /
    ``up_bits`` / ``dn_bits``, for result frames and the bits-vs-accuracy
    frontier).  The sweep engine stacks these over its config grid so
    batched SNR/outage draws stay bitwise-equal to the per-point loop;
    a codec that shrinks the payload shrinks the slot counts, so channel
    latency responds to compression on both paths.
    """
    from .model import slots_needed

    p_up, bits_up = cfg.link_budget(True)
    p_dn, bits_dn = cfg.link_budget(False)
    pay = round_payload_bits(protocol, n_mod=n_mod, n_labels=n_labels,
                             sample_bits=sample_bits, n_seed=n_seed,
                             codec=codec)
    return {"p_up": p_up, "p_dn": p_dn,
            "up_slots_first": slots_needed(pay.up_first, bits_up),
            "up_slots": slots_needed(pay.up_steady, bits_up),
            "dn_slots": slots_needed(pay.dn, bits_dn),
            "up_bits_first": pay.up_first, "up_bits": pay.up_steady,
            "dn_bits": pay.dn}
