"""The link pipeline: every device<->server transfer as one seam.

Historically the transfer logic was spread over four places — the round
bodies in ``core.protocols`` (loop path) and the grid round step (sweep
path) each hand-rolled their channel draws and downlink masking, payload
accounting lived in ``channel.payload``, and the fading model in
``channel.model``.  This module collapses them into explicit stages that
BOTH round-loop paths call:

``encode -> channel -> decode``

* :func:`uplink_stage` — encode the per-device uplink payload with the
  config's codec (``channel.payload`` registry: identity / quantize /
  delta / dp_gaussian) and decode it server-side.  The payload is the
  soft-label table for the FD/FLD family and the model parameters for
  FL; ``identity`` is bitwise transparent and consumes no PRNG, so
  identity-codec runs reproduce the pre-pipeline histories exactly.
* :func:`LinkPlan` / :data:`channel_stage` — the host-side link plan
  (per-slot success probabilities + codec-aware decode-slot counts) and
  the traced SNR/outage draw it feeds.  Both paths consume the PRNG
  identically, which the sweep-vs-loop equivalence tests lock down.
* :func:`downlink_gout` / :func:`downlink_params` — the decode half of
  the downlink broadcast: per-device success gating, layout-agnostic
  over a ``(D, ...)`` loop round or a ``(G, D, ...)`` grid round.

Codec numeric parameters (quantization levels, DP sigma/clip) may be
traced per-config scalars — the sweep engine vmaps the stage over a
config grid, so ``quant_bits``/``dp_sigma`` sweep inside one compiled
program while the codec *family* stays a structural (per-program) axis.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..registry import canonical_protocol
from .model import compute_outcomes, round_trip_traced, slowest_ok_time
from .payload import (CodecSpec, decode_params, decode_table,
                      encode_params, encode_table, parse_codec,
                      round_slot_plan)

#: The traced channel draw both paths share (the sweep engine vmaps it
#: over per-config link budgets) — re-exported here so round bodies
#: depend on the pipeline, not on ``channel.model`` internals.
channel_stage = round_trip_traced


@dataclasses.dataclass(frozen=True)
class LinkPlan:
    """Host-side link plan of one (protocol, codec, channel) point: the
    per-slot success probabilities, the codec-aware decode-slot
    requirements, and the payload bits they came from.  ``draw`` runs
    the channel stage for one round on the loop path; the sweep engine
    stacks the same fields over its config grid and vmaps
    :data:`channel_stage` instead.

    ``n_links`` is the *participating* cohort of the round — under
    client sampling / churn only the cohort is on air, so success masks
    and the straggler stage span ``D_cohort`` links, not the pool
    (``FederatedTrainer.link_plan`` caches one plan per cohort size).
    The FDMA bandwidth split stays at the pool level
    (``ChannelConfig.num_devices``): sampling changes who transmits, not
    the spectrum plan."""
    p_up: float
    p_dn: float
    up_slots_first: int
    up_slots: int
    dn_slots: int
    up_bits_first: float
    up_bits: float
    dn_bits: float
    n_links: int
    t_max_slots: int
    tau_s: float
    # Straggler stage (disabled at the defaults): per-device compute
    # times ~ Exp(compute_mean_s), devices past deadline_s dropped from
    # the aggregation set exactly like uplink outages.
    compute_mean_s: float = 0.0
    deadline_s: float = float("inf")

    @classmethod
    def build(cls, protocol: str, ch, *, n_mod: int, n_labels: int,
              sample_bits: int = 0, n_seed: int = 0,
              codec="identity", n_links: int | None = None) -> "LinkPlan":
        plan = round_slot_plan(protocol, ch, n_mod=n_mod,
                               n_labels=n_labels, sample_bits=sample_bits,
                               n_seed=n_seed, codec=codec)
        return cls(p_up=plan["p_up"], p_dn=plan["p_dn"],
                   up_slots_first=plan["up_slots_first"],
                   up_slots=plan["up_slots"], dn_slots=plan["dn_slots"],
                   up_bits_first=plan["up_bits_first"],
                   up_bits=plan["up_bits"], dn_bits=plan["dn_bits"],
                   n_links=ch.num_devices if n_links is None else n_links,
                   t_max_slots=ch.t_max_slots, tau_s=ch.tau_s,
                   compute_mean_s=getattr(ch, "compute_mean_s", 0.0),
                   deadline_s=getattr(ch, "deadline_s", float("inf")))

    def uplink_bits(self, first_round: bool) -> float:
        return self.up_bits_first if first_round else self.up_bits

    def dispatch(self, key, first_round: bool) -> dict:
        """Launch one round's channel (+ straggler) draw WITHOUT
        blocking: the device computations are dispatched and their
        un-synchronized array handles returned for a later
        :meth:`collect`.

        This is the double-buffering seam: a link outcome is a pure
        function of ``(plan, key)`` — never of training state — so
        round ``p``'s draw can go on the wire while round ``p-1``'s
        local SGD is still running, and :meth:`collect` later blocks on
        arrays that by then are usually done.  Dispatch order (channel
        stage, then the ``fold_in(key, 7)`` straggler stage) is exactly
        :meth:`draw`'s, so serial and overlapped schedules consume the
        PRNG identically — the bitwise-equivalence contract the
        ``serial_max_dev == 0`` gate locks down.
        """
        out = channel_stage(
            key, self.p_up,
            self.up_slots_first if first_round else self.up_slots,
            self.p_dn, self.dn_slots, self.n_links, self.t_max_slots,
            self.tau_s)
        pending = {"out": out, "comp": None}
        if self.compute_mean_s > 0.0:
            pending["comp"] = compute_outcomes(
                jax.random.fold_in(key, 7), self.compute_mean_s,
                self.deadline_s, self.n_links)
        return pending

    def collect(self, pending: dict) -> dict:
        """Block on a :meth:`dispatch` handle and assemble the round's
        host-side link outcome (the ``np.asarray`` conversions are the
        synchronization points).

        With the straggler stage enabled, late devices are AND-masked
        out of ``up_ok`` (the server treats a late report exactly like
        an undecodable one) and the round latency extends by the
        slowest *finishing* device's compute time.
        """
        out = pending["out"]
        up_ok = np.asarray(out["up_ok"])
        latency_s = float(out["latency_s"])
        result = {"up_ok": up_ok, "dn_ok": np.asarray(out["dn_ok"]),
                  "t_up": out["t_up"], "t_dn": out["t_dn"]}
        if pending["comp"] is not None:
            t_comp, comp_ok = pending["comp"]
            comp_ok = np.asarray(comp_ok)
            result["up_ok"] = up_ok & comp_ok
            result["comp_ok"] = comp_ok
            result["t_comp_s"] = np.asarray(t_comp)
            result["n_straggle"] = int((~comp_ok).sum())
            latency_s += float(slowest_ok_time(jnp.asarray(t_comp),
                                               jnp.asarray(comp_ok),
                                               self.deadline_s))
        result["latency_s"] = latency_s
        return result

    def draw(self, key, first_round: bool) -> dict:
        """One round's channel outcome (strict-serial path): dispatch
        and immediately collect.  The async round program overlaps the
        two halves instead; this composition is its bitwise oracle."""
        return self.collect(self.dispatch(key, first_round))


# ---------------------------------------------------------------------------
# Uplink: encode -> (channel gates the result) -> decode
# ---------------------------------------------------------------------------

def uplink_stage(spec: CodecSpec, protocol: str, dev_params, favg, key,
                 dev_gout, g_params, levels=None, dp_sigma=None,
                 dp_clip=None):
    """Run one round's uplink payload through the codec for one config.

    ``dev_params``/``favg``/``dev_gout`` are device-axis-leading
    ``(D, ...)`` values; the sweep engine vmaps this whole function over
    its grid axis.  Returns ``(dev_params_rx, favg_rx)`` — what the
    server decodes; the protocol's non-payload half passes through
    untouched (devices always keep their own exact state — only the
    transmitted copy is coded).

    References come from receiver-tracked state both ends know: each
    device's ``dev_gout`` copy for soft-label delta coding, the round's
    starting global model for FL.  ``levels``/``dp_sigma``/``dp_clip``
    default to the spec's own (Python-float) parameters; the sweep
    engine passes traced per-config scalars instead.

    ``identity`` short-circuits before any PRNG use and returns its
    inputs unchanged — bitwise equal to the pre-pipeline round bodies.
    """
    proto = canonical_protocol(protocol)
    name = spec.name
    if name == "identity":
        return dev_params, favg
    levels = spec.levels if levels is None else levels
    dp_sigma = spec.dp_sigma if dp_sigma is None else dp_sigma
    dp_clip = spec.dp_clip if dp_clip is None else dp_clip
    if proto == "fl":
        num_dev = jax.tree.leaves(dev_params)[0].shape[0]
        dkeys = jax.random.split(key, num_dev)
        coded = jax.vmap(
            lambda p, k: encode_params(name, p, k, g_params, levels,
                                       dp_sigma, dp_clip))(dev_params,
                                                           dkeys)
        rx = jax.vmap(lambda p: decode_params(name, p, g_params))(coded)
        return rx, favg
    dkeys = jax.random.split(key, favg.shape[0])
    coded = jax.vmap(
        lambda f, k, r: encode_table(name, f, k, r, levels, dp_sigma,
                                     dp_clip))(favg, dkeys, dev_gout)
    rx = jax.vmap(lambda c, r: decode_table(name, c, r))(coded, dev_gout)
    return dev_params, rx


def make_uplink_stage(codec, protocol: str):
    """Close the static halves (codec family, protocol) over
    :func:`uplink_stage` — the shape both round bodies build once and
    call per round."""
    spec = parse_codec(codec)

    def stage(dev_params, favg, key, dev_gout, g_params, levels=None,
              dp_sigma=None, dp_clip=None):
        return uplink_stage(spec, protocol, dev_params, favg, key,
                            dev_gout, g_params, levels, dp_sigma, dp_clip)

    return stage


# ---------------------------------------------------------------------------
# Downlink: broadcast decode, gated per device by dn_ok
# ---------------------------------------------------------------------------

def downlink_gout(dev_gout, gout, dn_ok):
    """Deliver the new G_out table to the devices whose downlink decoded;
    the rest keep their previous copy.  Layout-agnostic: ``dev_gout``
    ``(..., D, C, C)``, ``gout`` ``(..., C, C)``, ``dn_ok`` ``(..., D)``
    — the loop path passes ``(D, ...)``, the grid path ``(G, D, ...)``."""
    return jnp.where(dn_ok[..., None, None], jnp.expand_dims(gout, -3),
                     dev_gout)


def downlink_params(dev_params, g_params, dn_ok):
    """Deliver the global model to the devices whose downlink decoded
    (FL / FLD-family downlink).  ``dev_params`` leaves ``(..., D, *p)``,
    ``g_params`` leaves ``(..., *p)``, ``dn_ok`` ``(..., D)``."""
    batch_ndim = dn_ok.ndim  # leading dims incl. the device axis

    def leaf(dp, gp):
        mask = dn_ok.reshape(dn_ok.shape + (1,) * (dp.ndim - batch_ndim))
        return jnp.where(mask, jnp.expand_dims(gp, batch_ndim - 1), dp)

    return jax.tree.map(leaf, dev_params, g_params)
