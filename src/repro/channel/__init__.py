"""Wireless channel model (Sec. II-C): Rayleigh block fading, SNR-threshold
decoding, FDMA uplink / multicast downlink, latency and outage — plus the
link pipeline (``encode -> channel -> decode``) every device<->server
transfer routes through."""
from .model import (ChannelConfig, compute_outcomes,  # noqa: F401
                    link_outcomes, round_trip, round_trip_traced,
                    simulate_link, slots_needed, slowest_ok_time)
from .payload import (CODECS, CodecSpec, RoundPayload,  # noqa: F401
                      parse_codec, payload_bits, round_payload_bits,
                      round_slot_plan)
from .pipeline import (LinkPlan, channel_stage, downlink_gout,  # noqa: F401
                       downlink_params, make_uplink_stage, uplink_stage)
