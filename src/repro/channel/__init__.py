"""Wireless channel model (Sec. II-C): Rayleigh block fading, SNR-threshold
decoding, FDMA uplink / multicast downlink, latency and outage."""
from .model import ChannelConfig, simulate_link, round_trip  # noqa: F401
from .payload import payload_bits  # noqa: F401
