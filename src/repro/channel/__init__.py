"""Wireless channel model (Sec. II-C): Rayleigh block fading, SNR-threshold
decoding, FDMA uplink / multicast downlink, latency and outage."""
from .model import (ChannelConfig, link_outcomes, round_trip,  # noqa: F401
                    round_trip_traced, simulate_link, slots_needed)
from .payload import payload_bits, round_slot_plan  # noqa: F401
