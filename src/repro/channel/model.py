"""Rayleigh block-fading link simulation (eq. 4).

SNR_{d,t} = P h_{d,t} r_d^-alpha / (W^y N_0),  h ~ Exp(1) IID.
A slot decodes iff SNR >= theta, delivering tau * W^y * log2(1 + theta)
bits.  Latency T^y = first slot where cumulative bits >= payload;
outage if T^y > T_max.

The draw itself lives in :func:`link_outcomes`, which accepts the success
probability and the required slot count as *traced* scalars — the
protocol-sweep engine (repro.sweep) vmaps it over per-config channel
regimes, while the host-side :func:`simulate_link`/:func:`round_trip`
wrappers feed it Python scalars.  Both paths therefore consume the PRNG
identically: equal keys and equal (p, slots) values give bitwise-equal
masks and latencies, which is what the sweep-vs-loop equivalence tests
lock down.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Paper Sec. IV defaults."""
    num_devices: int = 10
    num_channels: int = 2          # N_ch
    bandwidth_hz: float = 10e6     # W
    p_up_dbm: float = 23.0
    p_dn_dbm: float = 40.0
    distance_m: float = 1000.0     # r_d
    pathloss_exp: float = 4.0      # alpha
    noise_dbm_hz: float = -174.0   # N_0
    theta: float = 3.0             # target SNR (linear)
    tau_s: float = 1e-3            # slot / coherence time
    t_max_slots: int = 100
    # Straggler model: per-device local compute time ~ Exp(compute_mean_s)
    # drawn each round; a device past deadline_s is dropped from the
    # aggregation set exactly like an uplink outage.  The defaults
    # disable the stage entirely (no draw, no latency term).
    compute_mean_s: float = 0.0
    deadline_s: float = float("inf")

    def link_budget(self, up: bool) -> tuple[float, float]:
        """Returns (success probability per slot, bits per good slot)."""
        w = self.bandwidth_hz * (self.num_channels / self.num_devices
                                 if up else 1.0)
        p_tx = 10 ** (((self.p_up_dbm if up else self.p_dn_dbm) - 30) / 10)
        n0 = 10 ** ((self.noise_dbm_hz - 30) / 10)
        noise = w * n0
        mean_snr = p_tx * self.distance_m ** (-self.pathloss_exp) / noise
        p_success = math.exp(-self.theta / mean_snr)  # P(h >= theta/meanSNR)
        bits = self.tau_s * w * math.log2(1.0 + self.theta)
        return p_success, bits


def slots_needed(payload_bits: float, bits_per_slot: float) -> int:
    """Host-side decode-slot requirement for one payload (>= 1)."""
    return max(1, math.ceil(payload_bits / bits_per_slot))


def link_outcomes(key, p_success, slots, n_links: int, t_max_slots: int):
    """Traced core of the link draw: (latency_slots (n,), success (n,)).

    ``p_success`` and ``slots`` may be Python scalars or traced scalars;
    ``n_links``/``t_max_slots`` are static (they size the bernoulli draw).
    Latency is t_max for outage links (they spent the whole window
    trying), per Sec. II-C.
    """
    good = jax.random.bernoulli(key, p_success, (n_links, t_max_slots))
    cum = jnp.cumsum(good.astype(jnp.int32), axis=1)
    reached = cum >= slots
    latency = jnp.where(reached.any(axis=1),
                        jnp.argmax(reached, axis=1) + 1,
                        t_max_slots)
    return latency, reached.any(axis=1)


def slowest_ok_slots(t, ok, t_max_slots: int):
    """Slots spent waiting on the slowest *successful* link; the full
    window only when every link outages (they contribute nothing)."""
    return jnp.where(jnp.any(ok), jnp.max(jnp.where(ok, t, 0)), t_max_slots)


def compute_outcomes(key, mean_s, deadline_s, n_links: int):
    """Traced per-device compute-time draw for the straggler stage:
    t ~ Exp(mean_s) IID, a device "finishes" iff t <= deadline_s.

    Returns (compute_s (n,), finished (n,) bool).  ``mean_s`` and
    ``deadline_s`` may be traced scalars; ``n_links`` is static.  The
    stage keys off its own fold of the round key, so enabling it never
    perturbs the channel draw stream.
    """
    t = mean_s * jax.random.exponential(key, (n_links,))
    return t, t <= deadline_s


def slowest_ok_time(t, ok, deadline_s):
    """Seconds spent waiting on the slowest device that *finished*; the
    full deadline only when every device straggles (the server cannot
    know nobody will report until the deadline passes)."""
    return jnp.where(jnp.any(ok), jnp.max(jnp.where(ok, t, 0.0)),
                     deadline_s)


def simulate_link(key, cfg: ChannelConfig, payload_bits: float, up: bool,
                  n_links: int):
    """Simulate ``n_links`` independent links for one global update.

    Returns (latency_slots (n,), success (n,) bool).
    """
    p, bits = cfg.link_budget(up)
    return link_outcomes(key, p, slots_needed(payload_bits, bits), n_links,
                         cfg.t_max_slots)


def round_trip(key, cfg: ChannelConfig, up_bits: float, dn_bits: float):
    """One global update: per-device uplink (FDMA unicast) + downlink
    (multicast: one transmission, every device must decode it).

    Returns dict with per-device success masks and the round's latency in
    seconds: tau * (max successful T_up + max successful T_dn), as the
    server waits for the slowest *non-outage* device — outage links are
    pinned at t_max_slots and must not inflate the round (they contribute
    nothing to the update).  Only when every link of a direction outages
    does that direction cost the full T_max window.
    """
    ku, kd = jax.random.split(key)
    t_up, ok_up = simulate_link(ku, cfg, up_bits, True, cfg.num_devices)
    t_dn, ok_dn = simulate_link(kd, cfg, dn_bits, False, cfg.num_devices)

    latency_s = cfg.tau_s * (
        float(slowest_ok_slots(t_up, ok_up, cfg.t_max_slots)) +
        float(slowest_ok_slots(t_dn, ok_dn, cfg.t_max_slots)))
    return {"up_ok": ok_up, "dn_ok": ok_dn, "t_up": t_up, "t_dn": t_dn,
            "latency_s": latency_s}


def round_trip_traced(key, p_up, up_slots, p_dn, dn_slots, n_links: int,
                      t_max_slots: int, tau_s: float):
    """Fully-traced :func:`round_trip` for the protocol-sweep engine.

    ``p_up``/``p_dn`` (per-slot success probabilities) and
    ``up_slots``/``dn_slots`` (decode-slot requirements, precomputed
    host-side with :func:`slots_needed` so no traced-float ceil can drift
    from the loop path) may be per-config traced scalars; vmapping this
    function over them batches whole channel regimes into one draw.
    Given equal inputs it consumes the PRNG exactly like ``round_trip``.
    """
    ku, kd = jax.random.split(key)
    t_up, ok_up = link_outcomes(ku, p_up, up_slots, n_links, t_max_slots)
    t_dn, ok_dn = link_outcomes(kd, p_dn, dn_slots, n_links, t_max_slots)
    latency_s = tau_s * (slowest_ok_slots(t_up, ok_up, t_max_slots) +
                         slowest_ok_slots(t_dn, ok_dn, t_max_slots))
    return {"up_ok": ok_up, "dn_ok": ok_dn, "t_up": t_up, "t_dn": t_dn,
            "latency_s": latency_s}
