"""Synthetic datasets (the container is offline: no MNIST/CIFAR download).

``synthetic_images`` builds a learnable 10-class 28x28 'digit' task:
each class is a smooth random prototype (low-frequency Gaussian field)
plus per-sample structured noise and a random shift — linearly separable
enough for the paper's 12.5k-weight CNN to reach high accuracy, hard
enough that protocols differ.  Statistics match Sec. IV (|S_d|=500,
b_s = 8 bit x 28 x 28).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _class_prototypes(key, num_classes: int, side: int):
    """Low-frequency prototypes: random coarse grids upsampled."""
    coarse = jax.random.normal(key, (num_classes, 7, 7))
    up = jax.image.resize(coarse, (num_classes, side, side), "bilinear")
    up = up / jnp.max(jnp.abs(up), axis=(1, 2), keepdims=True)
    return up


def synthetic_images(key, n: int, num_classes: int = 10, side: int = 28,
                     noise: float = 0.35):
    """Returns (x (n, side, side, 1) in [0,1], y (n,) int32)."""
    kp, ky, kn, ks = jax.random.split(key, 4)
    protos = _class_prototypes(kp, num_classes, side)
    y = jax.random.randint(ky, (n,), 0, num_classes)
    base = protos[y]
    jitter = jax.random.normal(kn, (n, side, side)) * noise
    # per-sample small roll (translation invariance pressure)
    shifts = jax.random.randint(ks, (n, 2), -2, 3)

    def roll_one(img, sh):
        return jnp.roll(jnp.roll(img, sh[0], axis=0), sh[1], axis=1)

    x = jax.vmap(roll_one)(base + jitter, shifts)
    x = jax.nn.sigmoid(2.0 * x)  # squash to (0,1) ~ pixel intensities
    return x[..., None].astype(jnp.float32), y.astype(jnp.int32)


def synthetic_tokens(key, n_seqs: int, seq_len: int, vocab: int,
                     order: int = 2):
    """Markov-ish token streams for LM smoke tests: next token depends on a
    random linear hash of the previous ``order`` tokens (learnable)."""
    k1, k2 = jax.random.split(key)
    coefs = jax.random.randint(k1, (order,), 1, 97)

    def step(carry, key):
        prev = carry
        h = jnp.sum(prev * coefs) % vocab
        nxt = (h + jax.random.randint(key, (), 0, 3)) % vocab
        prev = jnp.concatenate([prev[1:], nxt[None]])
        return prev, nxt

    def one_seq(key):
        ki, ks = jax.random.split(key)
        init = jax.random.randint(ki, (order,), 0, vocab)
        _, toks = jax.lax.scan(step, init, jax.random.split(ks, seq_len))
        return toks

    keys = jax.random.split(k2, n_seqs)
    return jax.vmap(one_seq)(keys).astype(jnp.int32)
