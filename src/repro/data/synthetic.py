"""Synthetic datasets (the container is offline: no MNIST/CIFAR download).

``synthetic_images`` builds a learnable 10-class 28x28 'digit' task:
each class is a smooth random prototype (low-frequency Gaussian field)
plus per-sample structured noise and a random shift — linearly separable
enough for the paper's 12.5k-weight CNN to reach high accuracy, hard
enough that protocols differ.  Statistics match Sec. IV (|S_d|=500,
b_s = 8 bit x 28 x 28).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _class_prototypes(key, num_classes: int, side: int):
    """Low-frequency prototypes: random coarse grids upsampled."""
    coarse = jax.random.normal(key, (num_classes, 7, 7))
    up = jax.image.resize(coarse, (num_classes, side, side), "bilinear")
    up = up / jnp.max(jnp.abs(up), axis=(1, 2), keepdims=True)
    return up


def synthetic_images(key, n: int, num_classes: int = 10, side: int = 28,
                     noise: float = 0.35):
    """Returns (x (n, side, side, 1) in [0,1], y (n,) int32)."""
    kp, ky, kn, ks = jax.random.split(key, 4)
    protos = _class_prototypes(kp, num_classes, side)
    y = jax.random.randint(ky, (n,), 0, num_classes)
    base = protos[y]
    jitter = jax.random.normal(kn, (n, side, side)) * noise
    # per-sample small roll (translation invariance pressure)
    shifts = jax.random.randint(ks, (n, 2), -2, 3)

    def roll_one(img, sh):
        return jnp.roll(jnp.roll(img, sh[0], axis=0), sh[1], axis=1)

    x = jax.vmap(roll_one)(base + jitter, shifts)
    x = jax.nn.sigmoid(2.0 * x)  # squash to (0,1) ~ pixel intensities
    return x[..., None].astype(jnp.float32), y.astype(jnp.int32)


def synthetic_rgb_images(key, n: int, num_classes: int = 10,
                         side: int = 32, channels: int = 3,
                         noise: float = 0.35):
    """CIFAR-shaped task: (x (n, side, side, channels) in [0,1], y (n,)).

    Same recipe as :func:`synthetic_images` but with per-channel
    prototypes drawn from a coarser 8x8 grid, so classes are separable
    by color *and* spatial structure."""
    kp, ky, kn, ks = jax.random.split(key, 4)
    coarse = jax.random.normal(kp, (num_classes, 8, 8, channels))
    protos = jax.image.resize(
        coarse, (num_classes, side, side, channels), "bilinear")
    protos = protos / jnp.max(jnp.abs(protos), axis=(1, 2, 3),
                              keepdims=True)
    y = jax.random.randint(ky, (n,), 0, num_classes)
    base = protos[y]
    jitter = jax.random.normal(kn, (n, side, side, channels)) * noise
    shifts = jax.random.randint(ks, (n, 2), -3, 4)

    def roll_one(img, sh):
        return jnp.roll(jnp.roll(img, sh[0], axis=0), sh[1], axis=1)

    x = jax.vmap(roll_one)(base + jitter, shifts)
    x = jax.nn.sigmoid(2.0 * x)
    return x.astype(jnp.float32), y.astype(jnp.int32)


def synthetic_audio(key, n: int, num_classes: int = 12, frames: int = 32,
                    mels: int = 40, noise: float = 0.3):
    """Speech-commands-shaped task: (x (n, frames, mels, 1), y (n,)).

    Each class is a smooth random time-frequency 'formant track'
    (coarse 8x10 grid upsampled to frames x mels), jittered per sample
    and rolled along the *time* axis only — mel bins carry class
    identity, onsets do not.  The trailing channel axis keeps the
    layout image-like so every registered model applies unchanged."""
    kp, ky, kn, ks = jax.random.split(key, 4)
    coarse = jax.random.normal(kp, (num_classes, 8, 10))
    protos = jax.image.resize(
        coarse, (num_classes, frames, mels), "bilinear")
    protos = protos / jnp.max(jnp.abs(protos), axis=(1, 2), keepdims=True)
    y = jax.random.randint(ky, (n,), 0, num_classes)
    base = protos[y]
    jitter = jax.random.normal(kn, (n, frames, mels)) * noise
    shifts = jax.random.randint(ks, (n,), -4, 5)
    x = jax.vmap(lambda img, sh: jnp.roll(img, sh, axis=0))(
        base + jitter, shifts)
    x = jax.nn.sigmoid(2.0 * x)  # squash like a normalized log-mel gram
    return x[..., None].astype(jnp.float32), y.astype(jnp.int32)


def synthetic_tokens(key, n_seqs: int, seq_len: int, vocab: int,
                     order: int = 2):
    """Markov-ish token streams for LM smoke tests: next token depends on a
    random linear hash of the previous ``order`` tokens (learnable)."""
    k1, k2 = jax.random.split(key)
    coefs = jax.random.randint(k1, (order,), 1, 97)

    def step(carry, key):
        prev = carry
        h = jnp.sum(prev * coefs) % vocab
        nxt = (h + jax.random.randint(key, (), 0, 3)) % vocab
        prev = jnp.concatenate([prev[1:], nxt[None]])
        return prev, nxt

    def one_seq(key):
        ki, ks = jax.random.split(key)
        init = jax.random.randint(ki, (order,), 0, vocab)
        _, toks = jax.lax.scan(step, init, jax.random.split(ks, seq_len))
        return toks

    keys = jax.random.split(k2, n_seqs)
    return jax.vmap(one_seq)(keys).astype(jnp.int32)
