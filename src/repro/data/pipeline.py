"""Data pipeline: the task registry + batching for the federated runtime.

A :class:`TaskSpec` names one workload as a hashable value object — its
input shape, class count, per-sample uplink payload width, and the
procedural generator that materializes it (the container is offline, so
``digits``/``cifar``/``speech`` are synthetic stand-ins with the *real*
dataset's geometry: 28x28x1 @ 8 bit, 32x32x3 @ 8 bit, and a
speech-commands-shaped 32x40 log-mel gram @ 16 bit).  Payload widths
feed ``round_payload_bits``, so uplink latency responds to the task the
same way it would on the real data.

Name resolution (aliases + the shared ValueError) lives in
``repro.registry.canonical_task``; this module owns construction.
Batching helpers below are task-agnostic (JAX PRNG index sampling so
local training is fully traceable/vmappable).
"""
from __future__ import annotations

import dataclasses
import math

import jax

from ..registry import TASKS, canonical_task
from .synthetic import synthetic_audio, synthetic_images, synthetic_rgb_images


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One workload: shape/classes/payload width + a seeded generator.

    ``sample_bits`` is the uplink payload of ONE raw (or mixed) sample —
    ``bits_per_feature * prod(input_shape)`` — matching the paper's
    b_s = 8 bit x 28 x 28 accounting for the digit task.
    """
    name: str
    input_shape: tuple
    num_classes: int
    bits_per_feature: int

    @property
    def sample_bits(self) -> int:
        return self.bits_per_feature * math.prod(self.input_shape)

    def data(self, key, n: int, num_classes: int | None = None):
        """Materialize (x (n, *input_shape), y (n,)) with ``key``.

        ``num_classes`` overrides the task's default class count (the
        generators are class-count generic); shapes never change."""
        c = self.num_classes if num_classes is None else num_classes
        if self.name == "digits":
            return synthetic_images(key, n, num_classes=c,
                                    side=self.input_shape[0])
        if self.name == "cifar":
            return synthetic_rgb_images(key, n, num_classes=c,
                                        side=self.input_shape[0],
                                        channels=self.input_shape[2])
        if self.name == "speech":
            return synthetic_audio(key, n, num_classes=c,
                                   frames=self.input_shape[0],
                                   mels=self.input_shape[1])
        raise ValueError(f"TaskSpec {self.name!r} has no generator")


_TASK_SPECS = {
    "digits": TaskSpec("digits", (28, 28, 1), 10, 8),
    "cifar": TaskSpec("cifar", (32, 32, 3), 10, 8),
    "speech": TaskSpec("speech", (32, 40, 1), 12, 16),
}
assert set(_TASK_SPECS) == set(TASKS)


def parse_task(name: str) -> TaskSpec:
    """Resolve a task name (canonical or alias) to its :class:`TaskSpec`;
    unknown names raise ``canonical_task``'s shared ValueError."""
    return _TASK_SPECS[canonical_task(name)]


def device_batches(key, n_local: int, iters: int, batch_size: int):
    """(iters, batch_size) random sample indices into a device's dataset."""
    return jax.random.randint(key, (iters, batch_size), 0, n_local)


def global_batches(key, x, y, batch_size: int, steps: int):
    """Host-side iterator of random batches from a flat dataset."""
    n = x.shape[0]
    for s in range(steps):
        k = jax.random.fold_in(key, s)
        idx = jax.random.randint(k, (batch_size,), 0, n)
        yield x[idx], y[idx]
