"""Batching pipeline for the federated runtime: per-device index sampling
done with JAX PRNG so local training is fully traceable/vmappable."""
from __future__ import annotations

import jax


def device_batches(key, n_local: int, iters: int, batch_size: int):
    """(iters, batch_size) random sample indices into a device's dataset."""
    return jax.random.randint(key, (iters, batch_size), 0, n_local)


def global_batches(key, x, y, batch_size: int, steps: int):
    """Host-side iterator of random batches from a flat dataset."""
    n = x.shape[0]
    for s in range(steps):
        k = jax.random.fold_in(key, s)
        idx = jax.random.randint(k, (batch_size,), 0, n)
        yield x[idx], y[idx]
