"""IID / non-IID partitioning across federated devices (Sec. IV).

IID: every label has the same number of samples per device.
non-IID (paper's recipe): two randomly selected labels get 2 samples each,
every other label gets 62 samples (|S_d| = 500, N_L = 10).
Dirichlet: per-device label proportions drawn from Dir(alpha) — the
standard non-IID severity dial of the FD literature (alpha -> 0 collapses
each device onto few labels, alpha -> inf recovers IID).

:class:`PartitionSpec` names one partitioning recipe as a hashable value
object, so the protocol-sweep engine can carry *which partition a grid
point trains on* as grid axes (``partition``/``alpha``/``n_local``) and
build each distinct partition exactly once.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: Registered partitioning recipes (the valid values of the sweep
#: engine's ``partition`` axis).
PARTITION_SCHEMES = ("iid", "noniid", "dirichlet")


def partition_iid(x, y, num_devices: int, per_device: int, num_classes: int,
                  seed: int = 0):
    """Device-axis vectorized: the full (D, per_device) index matrix is
    built with one per-class slice + one batched in-row shuffle, instead of
    a per-device Python loop (classes short on samples are resampled with
    replacement, as before)."""
    rng = np.random.default_rng(seed)
    x, y = np.asarray(x), np.asarray(y)
    per_class = per_device // num_classes
    need = num_devices * per_class
    cols = []
    for c in range(num_classes):
        pool = rng.permutation(np.flatnonzero(y == c))
        if pool.size < need:  # class exhausted: resample
            extra = rng.choice(np.flatnonzero(y == c), need - pool.size)
            pool = np.concatenate([pool, extra])
        cols.append(pool[:need].reshape(num_devices, per_class))
    idx = np.concatenate(cols, axis=1)      # (D, per_class * num_classes)
    idx = rng.permuted(idx, axis=1)         # per-device shuffle, batched
    return x[idx], y[idx]


def partition_noniid(x, y, num_devices: int, num_classes: int = 10,
                     rare_labels: int = 2, rare_count: int = 2,
                     common_count: int = 62, seed: int = 0):
    """Device-axis vectorized like :func:`partition_iid`: the rare-label
    draw is one batched per-row permutation, each class pool is consumed
    by all devices in a single slice (devices get disjoint samples until a
    class runs out, then the shortfall is resampled with replacement), and
    the (D, per_device) index matrix is assembled with one stable sort +
    one batched in-row shuffle — no per-device Python loop."""
    rng = np.random.default_rng(seed)
    x, y = np.asarray(x), np.asarray(y)
    per_device = (rare_labels * rare_count
                  + (num_classes - rare_labels) * common_count)
    # (D, rare_labels) distinct rare classes per device, batched
    rare = rng.permuted(
        np.tile(np.arange(num_classes), (num_devices, 1)),
        axis=1)[:, :rare_labels]
    counts = np.full((num_devices, num_classes), common_count, np.int64)
    np.put_along_axis(counts, rare, rare_count, axis=1)

    dev_of, samp = [], []
    for c in range(num_classes):
        need = counts[:, c]
        total = int(need.sum())
        pool = rng.permutation(np.flatnonzero(y == c))
        if pool.size < total:  # recycle if exhausted
            extra = rng.choice(np.flatnonzero(y == c), total - pool.size)
            pool = np.concatenate([pool, extra])
        dev_of.append(np.repeat(np.arange(num_devices), need))
        samp.append(pool[:total])
    dev_of = np.concatenate(dev_of)
    samp = np.concatenate(samp)
    order = np.argsort(dev_of, kind="stable")
    idx = samp[order].reshape(num_devices, per_device)
    idx = rng.permuted(idx, axis=1)             # per-device shuffle, batched
    return x[idx], y[idx]


def partition_dirichlet(x, y, num_devices: int, per_device: int,
                        num_classes: int, alpha: float = 1.0, seed: int = 0):
    """Dirichlet non-IID split: device d draws its per-class sample counts
    from Multinomial(per_device, q_d) with q_d ~ Dir(alpha * 1_C), then
    consumes the class pools with the same batched assembly as
    :func:`partition_noniid` (disjoint until a class runs out, then
    resampled with replacement).  Small ``alpha`` concentrates each device
    on few labels; large ``alpha`` approaches :func:`partition_iid`."""
    if alpha <= 0:
        raise ValueError(f"dirichlet alpha must be > 0, got {alpha}")
    rng = np.random.default_rng(seed)
    x, y = np.asarray(x), np.asarray(y)
    props = rng.dirichlet(np.full(num_classes, float(alpha)),
                          size=num_devices)            # (D, C)
    counts = np.stack([rng.multinomial(per_device, p) for p in props])

    dev_of, samp = [], []
    for c in range(num_classes):
        need = counts[:, c]
        total = int(need.sum())
        pool = rng.permutation(np.flatnonzero(y == c))
        if total and pool.size < total:  # recycle if exhausted
            extra = rng.choice(np.flatnonzero(y == c), total - pool.size)
            pool = np.concatenate([pool, extra])
        dev_of.append(np.repeat(np.arange(num_devices), need))
        samp.append(pool[:total])
    dev_of = np.concatenate(dev_of)
    samp = np.concatenate(samp)
    order = np.argsort(dev_of, kind="stable")
    idx = samp[order].reshape(num_devices, per_device)
    idx = rng.permuted(idx, axis=1)             # per-device shuffle, batched
    return x[idx], y[idx]


@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """One partitioning recipe as a hashable value object.

    ``scheme`` selects the partitioner; ``n_local`` is the per-device
    sample count |S_d| (for the paper's ``noniid`` recipe the common-label
    count is scaled so the row sums to ``n_local``); ``alpha`` is the
    Dirichlet concentration (``dirichlet`` scheme only); ``seed`` drives
    the partitioner's RNG.  Frozen + hashable so sweep grids can group
    points by the partition they train on and build each distinct
    partition exactly once.
    """
    scheme: str = "iid"
    n_local: int = 500
    alpha: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.scheme not in PARTITION_SCHEMES:
            raise ValueError(f"unknown partition scheme {self.scheme!r}; "
                             f"one of {PARTITION_SCHEMES}")
        if self.n_local < 1:
            raise ValueError(f"n_local must be >= 1, got {self.n_local}")
        if self.alpha <= 0:
            raise ValueError(f"alpha must be > 0, got {self.alpha}")

    def build(self, x, y, num_devices: int, num_classes: int):
        """Materialize the (dev_x (D, n_local, ...), dev_y (D, n_local))
        partition from a flat sample pool."""
        if np.asarray(y).ndim != 1:
            raise ValueError(
                "PartitionSpec.build partitions a flat sample pool "
                f"(y must be 1-D, got shape {np.asarray(y).shape}); "
                "partitioned grids take the raw pool, not (D, n) data")
        if self.scheme == "iid":
            return partition_iid(x, y, num_devices, self.n_local,
                                 num_classes, seed=self.seed)
        if self.scheme == "dirichlet":
            return partition_dirichlet(x, y, num_devices, self.n_local,
                                       num_classes, alpha=self.alpha,
                                       seed=self.seed)
        # paper's noniid recipe, with the common-label count scaled so the
        # per-device row sums to n_local (rare labels keep 2 x 2 samples)
        rare_labels, rare_count = 2, 2
        common = ((self.n_local - rare_labels * rare_count)
                  // (num_classes - rare_labels))
        if common < 1:
            raise ValueError(
                f"n_local={self.n_local} too small for the noniid recipe "
                f"with {num_classes} classes (needs >= "
                f"{rare_labels * rare_count + num_classes - rare_labels})")
        n_eff = rare_labels * rare_count + (num_classes - rare_labels) * \
            common
        if n_eff != self.n_local:
            raise ValueError(
                f"noniid n_local must satisfy n_local = {rare_labels}*"
                f"{rare_count} + {num_classes - rare_labels}*common; "
                f"nearest to {self.n_local} is {n_eff}")
        return partition_noniid(x, y, num_devices, num_classes,
                                rare_labels=rare_labels,
                                rare_count=rare_count,
                                common_count=common, seed=self.seed)
