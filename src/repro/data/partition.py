"""IID / non-IID partitioning across federated devices (Sec. IV).

IID: every label has the same number of samples per device.
non-IID (paper's recipe): two randomly selected labels get 2 samples each,
every other label gets 62 samples (|S_d| = 500, N_L = 10).
"""
from __future__ import annotations

import numpy as np


def partition_iid(x, y, num_devices: int, per_device: int, num_classes: int,
                  seed: int = 0):
    """Device-axis vectorized: the full (D, per_device) index matrix is
    built with one per-class slice + one batched in-row shuffle, instead of
    a per-device Python loop (classes short on samples are resampled with
    replacement, as before)."""
    rng = np.random.default_rng(seed)
    x, y = np.asarray(x), np.asarray(y)
    per_class = per_device // num_classes
    need = num_devices * per_class
    cols = []
    for c in range(num_classes):
        pool = rng.permutation(np.flatnonzero(y == c))
        if pool.size < need:  # class exhausted: resample
            extra = rng.choice(np.flatnonzero(y == c), need - pool.size)
            pool = np.concatenate([pool, extra])
        cols.append(pool[:need].reshape(num_devices, per_class))
    idx = np.concatenate(cols, axis=1)      # (D, per_class * num_classes)
    idx = rng.permuted(idx, axis=1)         # per-device shuffle, batched
    return x[idx], y[idx]


def partition_noniid(x, y, num_devices: int, num_classes: int = 10,
                     rare_labels: int = 2, rare_count: int = 2,
                     common_count: int = 62, seed: int = 0):
    """Device-axis vectorized like :func:`partition_iid`: the rare-label
    draw is one batched per-row permutation, each class pool is consumed
    by all devices in a single slice (devices get disjoint samples until a
    class runs out, then the shortfall is resampled with replacement), and
    the (D, per_device) index matrix is assembled with one stable sort +
    one batched in-row shuffle — no per-device Python loop."""
    rng = np.random.default_rng(seed)
    x, y = np.asarray(x), np.asarray(y)
    per_device = (rare_labels * rare_count
                  + (num_classes - rare_labels) * common_count)
    # (D, rare_labels) distinct rare classes per device, batched
    rare = rng.permuted(
        np.tile(np.arange(num_classes), (num_devices, 1)),
        axis=1)[:, :rare_labels]
    counts = np.full((num_devices, num_classes), common_count, np.int64)
    np.put_along_axis(counts, rare, rare_count, axis=1)

    dev_of, samp = [], []
    for c in range(num_classes):
        need = counts[:, c]
        total = int(need.sum())
        pool = rng.permutation(np.flatnonzero(y == c))
        if pool.size < total:  # recycle if exhausted
            extra = rng.choice(np.flatnonzero(y == c), total - pool.size)
            pool = np.concatenate([pool, extra])
        dev_of.append(np.repeat(np.arange(num_devices), need))
        samp.append(pool[:total])
    dev_of = np.concatenate(dev_of)
    samp = np.concatenate(samp)
    order = np.argsort(dev_of, kind="stable")
    idx = samp[order].reshape(num_devices, per_device)
    idx = rng.permuted(idx, axis=1)             # per-device shuffle, batched
    return x[idx], y[idx]
