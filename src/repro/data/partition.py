"""IID / non-IID partitioning across federated devices (Sec. IV).

IID: every label has the same number of samples per device.
non-IID (paper's recipe): two randomly selected labels get 2 samples each,
every other label gets 62 samples (|S_d| = 500, N_L = 10).
"""
from __future__ import annotations

import numpy as np


def partition_iid(x, y, num_devices: int, per_device: int, num_classes: int,
                  seed: int = 0):
    """Device-axis vectorized: the full (D, per_device) index matrix is
    built with one per-class slice + one batched in-row shuffle, instead of
    a per-device Python loop (classes short on samples are resampled with
    replacement, as before)."""
    rng = np.random.default_rng(seed)
    x, y = np.asarray(x), np.asarray(y)
    per_class = per_device // num_classes
    need = num_devices * per_class
    cols = []
    for c in range(num_classes):
        pool = rng.permutation(np.flatnonzero(y == c))
        if pool.size < need:  # class exhausted: resample
            extra = rng.choice(np.flatnonzero(y == c), need - pool.size)
            pool = np.concatenate([pool, extra])
        cols.append(pool[:need].reshape(num_devices, per_class))
    idx = np.concatenate(cols, axis=1)      # (D, per_class * num_classes)
    idx = rng.permuted(idx, axis=1)         # per-device shuffle, batched
    return x[idx], y[idx]


def partition_noniid(x, y, num_devices: int, num_classes: int = 10,
                     rare_labels: int = 2, rare_count: int = 2,
                     common_count: int = 62, seed: int = 0):
    rng = np.random.default_rng(seed)
    x, y = np.asarray(x), np.asarray(y)
    by_class = [list(rng.permutation(np.flatnonzero(y == c))) for c in
                range(num_classes)]
    dev_x, dev_y = [], []
    for _ in range(num_devices):
        rare = rng.choice(num_classes, rare_labels, replace=False)
        idx = []
        for c in range(num_classes):
            want = rare_count if c in rare else common_count
            take, by_class[c] = by_class[c][:want], by_class[c][want:]
            if len(take) < want:  # recycle if exhausted
                extra = rng.choice(np.flatnonzero(y == c),
                                   want - len(take)).tolist()
                take = list(take) + extra
            idx.extend(take)
        idx = np.array(idx)
        rng.shuffle(idx)
        dev_x.append(x[idx])
        dev_y.append(y[idx])
    return np.stack(dev_x), np.stack(dev_y)
