"""Data substrate: synthetic corpora (offline container) + partitioners."""
from .synthetic import synthetic_images, synthetic_tokens  # noqa: F401
from .partition import partition_iid, partition_noniid  # noqa: F401
from .pipeline import device_batches  # noqa: F401
