"""Data substrate: synthetic corpora (offline container) + partitioners."""
from .synthetic import synthetic_images, synthetic_tokens  # noqa: F401
from .partition import (PARTITION_SCHEMES, PartitionSpec,  # noqa: F401
                        partition_dirichlet, partition_iid,
                        partition_noniid)
from .pipeline import device_batches  # noqa: F401
