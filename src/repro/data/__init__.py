"""Data substrate: synthetic corpora (offline container), the task
registry, and partitioners."""
from .synthetic import (synthetic_audio, synthetic_images,  # noqa: F401
                        synthetic_rgb_images, synthetic_tokens)
from .partition import (PARTITION_SCHEMES, PartitionSpec,  # noqa: F401
                        partition_dirichlet, partition_iid,
                        partition_noniid)
from .pipeline import TaskSpec, device_batches, parse_task  # noqa: F401
