"""The compiled protocol-sweep runner.

``SweepRunner`` turns a :class:`~repro.sweep.axes.SweepGrid` into as few
jitted programs as the grid's structure allows: per-config constants
(step sizes, conversion budgets, link budgets, padded seed sets, PRNG
keys, device partitions) are stacked along a leading grid axis G, the
per-round protocol step from ``repro.core.protocols.make_grid_round_step``
is vmapped over that axis, and ``jax.lax.scan`` drives it over rounds —
so a grid of G configs × D devices × R rounds executes without returning
to Python.  Two axes cannot batch into one program and are handled
structurally instead:

* **protocol** — round bodies differ across protocols (FL aggregates
  models, FD only output tables, the FLD family converts outputs to a
  model), so the runner groups grid points by protocol and compiles ONE
  vmapped scan per distinct protocol (``engine_stats`` counts traces;
  the heterogeneous-grid tests assert program count == #protocols);
* **partition** — points may train on different device partitions
  (``partition``/``alpha``/``n_local`` axes).  Each *distinct*
  :class:`~repro.data.partition.PartitionSpec` is built exactly once,
  ragged ``n_local`` partitions are zero-padded to the grid maximum and
  stacked per-config, and the traced per-config ``n_local`` batch-draw
  bound masks the pad rows (identical draws to the loop path's static
  bound).

With ``shard_devices`` set on the base config, the device axis
additionally runs under ``shard_map`` on the 1-D "data" mesh (the same
placement the trainer uses), composing grid-vmap × device-sharding.

Everything the compiled programs cannot express is absorbed host-side
*before* the scans, in exactly the per-point order the loop path uses:

* round-1 seed collection (sort-based pairing + cycle search) runs once
  per *seed group* via the content-keyed ``core.seed_prep`` memo — the
  key fingerprints the partition, so heterogeneous-partition grids prep
  once per distinct (config fields, partition, key) content, not once
  per point — then pads the ragged train sets to the grid maximum
  (``n_train`` masks the `randint` draws onto the live prefix);
* conversion step keys are precomputed per (round, config) because
  ``jax.random.split`` is not prefix-stable across split counts;
* channel link budgets reduce to per-slot success probabilities and
  decode-slot counts (``round_slot_plan``), so traced draws stay
  bitwise-equal to the loop path.

The sweep-vs-loop equivalence tests (tests/test_sweep.py) assert the
whole per-round history matches ``FederatedTrainer.run`` per grid point,
heterogeneous grids included.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 graduated shard_map out of experimental
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

from ..channel import round_slot_plan
from ..core.privacy import GaussianAccountant, gaussian_epsilon
from ..core.program import GridRoundProgram, ProgramOptions
from ..core.protocols import (FLD_FAMILY, FederatedTrainer,
                              gout_update_psum, make_grid_local_train,
                              make_grid_round_step, weighted_avg_psum)
from ..core.seed_prep import SeedPrepMemo, prepare_seeds
from ..core.state import RoundState
from ..data.pipeline import parse_task
from ..launch.mesh import (_largest_divisor, make_device_mesh,
                           make_grid_mesh)
from ..registry import MODELS, TASKS
from .axes import SweepGrid
from .results import SweepResult


@dataclasses.dataclass
class EngineStats:
    """Trace/lower instrumentation: ``programs`` counts compiled-program
    *builds*, ``traces`` counts actual jit trace events (the counter is a
    Python side effect inside the jitted scan wrapper, so warm calls do
    not increment it).  The heterogeneous-grid tests assert a mixed
    protocol grid traces exactly once per distinct protocol."""
    programs: int = 0
    traces: int = 0

    def reset(self):
        self.programs = 0
        self.traces = 0


engine_stats = EngineStats()


def _pad_seed_sets(seed_sets, num_classes: int):
    """Stack ragged per-config train sets: (G, Nmax, ...) x, (G, Nmax[, C])
    y, (G,) live sizes.  Memoized seed prep hands grid points that share a
    seed key the *same* result object, so padding runs once per unique set
    and the stacked consts are fancy-indexed copies of those rows.  Mixed
    hard/soft grids (e.g. a ``lam`` axis that crosses 0.5) promote hard
    labels to one-hot rows — the conversion losses are identical for
    one-hot targets, so only mixed grids pay the (ulp-level) formulation
    change."""
    uniq_of: dict[int, int] = {}
    uniq, inv = [], []
    for s in seed_sets:
        u = uniq_of.get(id(s))
        if u is None:
            u = uniq_of[id(s)] = len(uniq)
            uniq.append(s)
        inv.append(u)
    xs = [np.asarray(s["train_x"]) for s in uniq]
    ys = [np.asarray(s["train_y"]) for s in uniq]
    n = np.asarray([x.shape[0] for x in xs], np.int32)
    n_max = int(n.max())
    feat = xs[0].shape[1:]
    px = np.zeros((len(xs), n_max) + feat, np.float32)
    for u, x in enumerate(xs):
        px[u, :x.shape[0]] = x
    hard = [y.ndim == 1 for y in ys]
    if all(hard):
        py = np.zeros((len(ys), n_max), np.int32)
        for u, y in enumerate(ys):
            py[u, :y.shape[0]] = y
    else:
        py = np.zeros((len(ys), n_max, num_classes), np.float32)
        for u, y in enumerate(ys):
            if y.ndim == 1:
                y = np.eye(num_classes, dtype=np.float32)[y]
            py[u, :y.shape[0]] = y
    inv = np.asarray(inv)
    return px[inv], py[inv], n[inv]


def _stack_partitions(parts):
    """Stack the per-point device partitions of one protocol group.

    ``parts``: list of (dev_x, dev_y) pairs, one per point — points
    sharing a :class:`PartitionSpec` share the *same* array objects, so
    identity dedup keeps padding O(#distinct partitions).  Returns
    ``(dev_x, dev_y, n_local (G,), per_config)``: a group whose points
    all train on one partition keeps the single (D, n, ...) arrays
    (``per_config=False``, the classic homogeneous layout); otherwise
    ragged ``n_local`` partitions are zero-padded to the group maximum
    and stacked to (G, D, Nmax, ...).  Pad rows are never sampled: the
    traced per-config ``n_local`` bounds every batch draw."""
    n_local = np.asarray([x.shape[1] for x, _ in parts], np.int32)
    if len({id(x) for x, _ in parts}) == 1:
        x, y = parts[0]
        return jnp.asarray(x), jnp.asarray(y), n_local, False
    uniq_of: dict[int, int] = {}
    uniq, inv = [], []
    for pair in parts:
        u = uniq_of.get(id(pair[0]))
        if u is None:
            u = uniq_of[id(pair[0])] = len(uniq)
            uniq.append(pair)
        inv.append(u)
    xs = [np.asarray(x) for x, _ in uniq]
    ys = [np.asarray(y) for _, y in uniq]
    n_max = int(max(x.shape[1] for x in xs))
    D = xs[0].shape[0]
    feat = xs[0].shape[2:]
    px = np.zeros((len(xs), D, n_max) + feat, np.float32)
    py = np.zeros((len(ys), D, n_max), ys[0].dtype)
    for u, (x, y) in enumerate(zip(xs, ys)):
        px[u, :, :x.shape[1]] = x
        py[u, :, :y.shape[1]] = y
    inv = np.asarray(inv)
    return jnp.asarray(px[inv]), jnp.asarray(py[inv]), n_local, True


def _resolve_partitions(grid: SweepGrid, dev_x, dev_y, num_devices: int,
                        num_classes: int):
    """Per-point (dev_x, dev_y) pairs.  Partitioned grids build each
    distinct :class:`PartitionSpec` exactly once from the flat sample
    pool; classic grids share the given pre-partitioned arrays (one
    object, so downstream identity dedup and the seed-prep fingerprint
    cache both see a single partition)."""
    if dev_x is None or dev_y is None:
        raise ValueError(
            "grid without a task axis takes explicit data: pass "
            "dev_x/dev_y (and test_x/test_y), or task_data=... / "
            "make_task_data(grid) to draw the base task's procedural "
            "pool")
    if grid.partitioned:
        pool_x, pool_y = np.asarray(dev_x), np.asarray(dev_y)
        if pool_y.ndim != 1:
            raise ValueError(
                "grids with partition axes take the flat sample pool "
                f"(x (N, ...), y (N,)); got y shape {pool_y.shape} — "
                "pass the unpartitioned data and let each point's "
                "PartitionSpec split it")
        built: dict = {}
        for spec in grid.parts:
            if spec not in built:
                built[spec] = spec.build(pool_x, pool_y, num_devices,
                                         num_classes)
        return [built[spec] for spec in grid.parts]
    if np.asarray(dev_y).ndim != 2:
        raise ValueError(
            "grids without partition axes take pre-partitioned "
            f"(D, n_local) data; got dev_y shape "
            f"{np.asarray(dev_y).shape}")
    shared = (dev_x, dev_y)
    return [shared] * grid.size


def make_task_data(grid: SweepGrid, n_test: int = 200,
                   data_seed: int = 1234) -> dict:
    """Materialize one procedural sample pool + test set per distinct
    task of a tasked grid: ``{task: (pool_x, pool_y, test_x, test_y)}``.

    Pools are sized for the largest partition any point of the task
    requests (``num_devices * n_local``), drawn from a per-task fold of
    ``data_seed`` so every task's data is deterministic and independent
    of grid layout.  Pass the result (or your own dict with the same
    layout) to :class:`SweepRunner` / :func:`run_pointwise` as
    ``task_data``."""
    out = {}
    for task, idxs in grid.task_groups().items():
        spec = parse_task(task)
        fc0 = grid.points[idxs[0]][0]
        if not grid.partitioned:
            raise ValueError("make_task_data needs a partitioned grid "
                             "(task axes always are)")
        n_pool = max(grid.points[g][0].num_devices * grid.parts[g].n_local
                     for g in idxs)
        key = jax.random.fold_in(jax.random.PRNGKey(data_seed),
                                 TASKS.index(spec.name))
        x, y = spec.data(key, n_pool + n_test, fc0.num_classes)
        out[task] = (np.asarray(x[:n_pool]), np.asarray(y[:n_pool]),
                     np.asarray(x[n_pool:]), np.asarray(y[n_pool:]))
    return out


def _resolve_task_partitions(grid: SweepGrid, task_data: dict):
    """Per-point (dev_x, dev_y) pairs for a tasked grid: each distinct
    (task, PartitionSpec) pair is built exactly once from that task's
    pool (identity-shared arrays keep the seed-prep fingerprint cache
    and stacking dedup effective)."""
    missing = set(grid.task_groups()) - set(task_data)
    if missing:
        raise ValueError(f"task_data is missing pools for {sorted(missing)}")
    built: dict = {}
    parts = []
    for (fc, _), spec in zip(grid.points, grid.parts):
        key = (fc.task, spec)
        if key not in built:
            px, py = task_data[fc.task][:2]
            built[key] = spec.build(px, py, fc.num_devices, fc.num_classes)
        parts.append(built[key])
    return parts


def _group_models(model, fc):
    """Resolve one program group's models: the caller-supplied object for
    classic grids, else registry builds from the group's (model, task)
    identity.  Returns ``(global_model, arch_models)`` where
    ``arch_models`` is None for homogeneous cohorts or the ordered
    ``[(name, device_indices, model), ...]`` groups (first group =
    server architecture = device 0, the round-robin contract the grid
    step relies on)."""
    if model is not None:
        return model, None
    models = fc.build_models()
    groups = fc.arch_groups()
    gmodel = models[fc.server_model()]
    if groups is None:
        return gmodel, None
    if groups[0][0] != fc.server_model():
        raise ValueError(
            "grid programs require device 0 to run the server "
            f"architecture ({fc.server_model()!r}); the partition "
            f"starts with {groups[0][0]!r}")
    return gmodel, [(a, idx, models[a]) for a, idx in groups]


class _ProtocolProgram:
    """One compiled program: every grid point of one protocol.  This is
    the stacking/tracing core the homogeneous runner used to be, now
    scoped to a protocol group (``idxs``, in grid order) with per-config
    partitions."""

    def __init__(self, model, grid: SweepGrid, proto: str, idxs, parts,
                 test_x, test_y, memo: SeedPrepMemo, mesh,
                 codec: str = "identity", cohort_size: int | None = None,
                 arch_models: list | None = None,
                 options: ProgramOptions | None = None):
        engine_stats.programs += 1
        fc0, ch0 = grid.points[idxs[0]]
        self.idxs = idxs
        self.codec = codec
        self.options = options or ProgramOptions()
        points = [grid.points[i] for i in idxs]
        G, D, C, R = len(idxs), fc0.num_devices, fc0.num_classes, \
            fc0.max_rounds
        # client sampling: the cohort size is part of this group's
        # structural identity (program_groups), so every point agrees
        Dc = D if cohort_size is None else min(int(cohort_size), D)
        sampled = Dc < D
        dev_x, dev_y, n_local, per_config = _stack_partitions(parts)
        feat = dev_x.shape[3:] if per_config else dev_x.shape[2:]
        if self.options.mesh_shape is not None:
            # pod-scale 2-D (grid x device) mesh: this group's G points
            # lay out along "grid", each point's cohort along "data".
            # The requested shape is a *budget* — each program group
            # re-fits it to its own grid slice (the largest divisors
            # that fit the request AND the local chip count), so a
            # 5-point group on a 2x4 request, or a 2x4 request on a
            # 1-chip host, still shards what it can instead of erroring.
            avail = len(jax.devices())
            gs = _largest_divisor(G, min(self.options.mesh_shape[0],
                                         avail))
            ds = _largest_divisor(Dc, min(self.options.mesh_shape[1],
                                          avail // gs))
            mesh = make_grid_mesh(G, Dc, shape=(gs, ds))
        elif sampled and mesh is not None:
            # the mesh spans the cohort (only Dc devices enter the
            # shard_mapped fns), mirroring the sampled trainer's mesh
            mesh = make_device_mesh(Dc, fc0.mesh_shards or None)
        self.mesh_shape = (tuple(mesh.devices.shape)
                           if mesh is not None else None)

        # ---- host prep, per config in the loop path's exact key order;
        # seed prep is memoized on the seed-determining content (config
        # fields + partition fingerprint + key bytes), so points sharing
        # a seed key — and, across partitions, distinct points sharing
        # one partition's content — share one result object ----
        run_keys, inits, conv_keys, seed_sets = [], [], [], []
        # mixed cohorts: per-point inits for the non-server architectures
        # (the server architecture's group shares the global init, the
        # same stream contract as FederatedTrainer.init_state)
        arch_inits = {a: [] for a, _, _ in (arch_models or [])[1:]}
        plans = {"p_up": [], "p_dn": [], "up1": [], "up": [], "dn": [],
                 "up_bits1": [], "up_bits": []}
        specs = [fc.codec_spec() for fc, _ in points]
        k_max = max(fc.server_iters for fc, _ in points)
        # sampled groups prep seeds on the round-1 *cohort* slice of each
        # partition (the loop path collects from the gathered cohort);
        # gathers are cached by (partition identity, cohort content) so
        # points sharing both still share one array object — keeping the
        # seed-prep memo's identity/fingerprint dedup effective
        gather_cache: dict = {}
        for (fc, ch), spec, (px, py) in zip(points, specs, parts):
            kinit, key = jax.random.split(jax.random.PRNGKey(fc.seed))
            run_keys.append(np.asarray(key))
            params = model.init(kinit)
            inits.append(params)
            for a, _, m in (arch_models or [])[1:]:
                arch_inits[a].append(m.init(
                    jax.random.fold_in(kinit, MODELS.index(a) + 1)))
            n_mod = sum(p.size for p in jax.tree.leaves(params))
            if proto in FLD_FAMILY:
                spx, spy = px, py
                if sampled:
                    c1 = fc.sampler().cohort(fc.seed, 1, D)
                    ckey = (id(px), c1.tobytes())
                    pair = gather_cache.get(ckey)
                    if pair is None:
                        pair = (np.asarray(px)[c1], np.asarray(py)[c1])
                        gather_cache[ckey] = pair
                    spx, spy = pair
                kr1 = jax.random.fold_in(key, 1)
                seed_sets.append(prepare_seeds(
                    fc, spx, spy, jax.random.fold_in(kr1, 2), memo=memo))
                ck = np.zeros((R, k_max, 2), np.uint32)
                for p in range(1, R + 1):
                    base = jax.random.fold_in(jax.random.fold_in(key, p), 4)
                    ck[p - 1, :fc.server_iters] = np.asarray(
                        jax.random.split(base, fc.server_iters))
                conv_keys.append(ck)
            plan = round_slot_plan(
                proto, ch, n_mod=n_mod, n_labels=C,
                sample_bits=fc.sample_bits, n_seed=fc.n_seed, codec=spec)
            plans["p_up"].append(plan["p_up"])
            plans["p_dn"].append(plan["p_dn"])
            plans["up1"].append(plan["up_slots_first"])
            plans["up"].append(plan["up_slots"])
            plans["dn"].append(plan["dn_slots"])
            plans["up_bits1"].append(plan["up_bits_first"])
            plans["up_bits"].append(plan["up_bits"])

        g_params = jax.tree.map(lambda *ls: jnp.stack(ls), *inits)
        n_params = sum(p[0].size for p in jax.tree.leaves(g_params))

        consts = {
            "key": jnp.asarray(np.stack(run_keys)),
            "eta": jnp.asarray([fc.eta for fc, _ in points], jnp.float32),
            "beta": jnp.asarray([fc.beta for fc, _ in points],
                                jnp.float32),
            "s_iters": jnp.asarray(
                [fc.server_iters for fc, _ in points], jnp.int32),
            "eps": jnp.asarray([fc.eps for fc, _ in points], jnp.float32),
            "n_local": jnp.asarray(n_local),
            "p_up": jnp.asarray(plans["p_up"], jnp.float32),
            "p_dn": jnp.asarray(plans["p_dn"], jnp.float32),
        }
        if codec != "identity":
            # codec numeric parameters batch as traced per-config scalars
            # (the codec *family* is this program's structural identity)
            consts["q_levels"] = jnp.asarray(
                [s.levels for s in specs], jnp.float32)
            consts["dp_sigma"] = jnp.asarray(
                [s.dp_sigma for s in specs], jnp.float32)
            consts["dp_clip"] = jnp.asarray(
                [s.dp_clip for s in specs], jnp.float32)

        # per-point link accounting for result frames (host floats; the
        # bits -> slots mapping already shaped the compiled plans above)
        self.up_bits_first = np.asarray(plans["up_bits1"], np.float64)
        self.up_bits_steady = np.asarray(plans["up_bits"], np.float64)
        self.dp_epsilon = np.asarray(
            [gaussian_epsilon(s.dp_sigma, s.dp_delta, R)
             if s.name == "dp_gaussian" else np.nan for s in specs])
        # full DP ledgers, participation-aware: stepped through the same
        # accountant (with the same per-round cohorts) the loop path's
        # run() uses, so sweep histories carry identical history["dp"]
        self.dp_ledgers = []
        for (fc, _), s in zip(points, specs):
            if s.name != "dp_gaussian":
                self.dp_ledgers.append(None)
                continue
            acct = GaussianAccountant(s.dp_sigma, s.dp_delta,
                                      sample_ratio=fc.sample_ratio)
            smp = fc.sampler()
            for p in range(1, R + 1):
                acct.step(cohort=(smp.cohort(fc.seed, p, D) if sampled
                                  else None))
            self.dp_ledgers.append(acct.ledger())
        self.dp_epsilon_device = np.asarray(
            [led["epsilon_device_max"] if led else np.nan
             for led in self.dp_ledgers])
        if proto in FLD_FAMILY:
            sx, sy, n_train = _pad_seed_sets(seed_sets, C)
            consts["seeds_x"] = jnp.asarray(sx)
            consts["seeds_y"] = jnp.asarray(sy)
            consts["n_train"] = jnp.asarray(n_train)
            ck = jnp.asarray(np.stack(conv_keys, axis=1))  # (R, G, Kmax, 2)
        else:
            consts["seeds_x"] = jnp.zeros((G, 1) + feat)
            consts["seeds_y"] = jnp.zeros((G, 1), jnp.int32)
            consts["n_train"] = jnp.ones((G,), jnp.int32)
            ck = jnp.zeros((R, G, 1, 2), jnp.uint32)

        up_slots = np.tile(np.asarray(plans["up"], np.int32), (R, 1))
        up_slots[0] = np.asarray(plans["up1"], np.int32)
        self._xs = {
            "p": jnp.arange(1, R + 1, dtype=jnp.int32),
            "up_slots": jnp.asarray(up_slots),
            "dn_slots": jnp.tile(jnp.asarray(plans["dn"], jnp.int32)[None],
                                 (R, 1)),
            "conv_keys": ck,
        }
        if sampled:
            # every round's cohort, host-drawn per point: (R, G, Dc)
            # gather indices for the compiled scan (unsampled groups get
            # no "cohort" input at all — graph-identical to the classic
            # step)
            cohorts = np.stack([
                np.stack([fc.sampler().cohort(fc.seed, p, D)
                          for fc, _ in points])
                for p in range(1, R + 1)])
            self._xs["cohort"] = jnp.asarray(cohorts, jnp.int32)

        # ---- device-axis placement: vmapped, or shard_mapped over the
        # "data" mesh exactly like the trainer's sharded path ----
        fns = {}
        if mesh is not None:
            # a sampled gather hands local_train per-config (G, Dc, ...)
            # batches even off shared data, so the in_axes/in_specs
            # follow the per-config layout whenever sampling is on
            grid_lt = make_grid_local_train(model.apply, C,
                                            fc0.local_iters,
                                            fc0.local_batch,
                                            per_config or sampled)
            # on a 2-D ("grid", "data") mesh the (G, D, ...) state shards
            # both axes and the per-config (G,) scalars shard "grid";
            # every reduction stays a psum over "data" only, so each grid
            # shard's collective spans exactly its own points' device
            # rows — no cross-point communication is introduced.  On the
            # 1-D ("data",) mesh gcfg degrades to P() (replicated),
            # recovering the previous specs verbatim.
            grid_axis = "grid" in mesh.axis_names
            gdev = P("grid", "data") if grid_axis else P(None, "data")
            gcfg = P("grid") if grid_axis else P()
            ddev = gdev if (per_config or sampled) else P("data")
            rep = P()
            fns["local_train_fn"] = shard_map(
                grid_lt, mesh=mesh,
                in_specs=(gdev, ddev, ddev, gdev, gdev, rep, gcfg, gcfg,
                          gcfg),
                out_specs=(gdev, gdev, gdev, gdev), check_rep=False)
            fns["weighted_avg_fn"] = shard_map(
                jax.vmap(weighted_avg_psum), mesh=mesh,
                in_specs=(gdev, gdev), out_specs=gcfg, check_rep=False)
            fns["gout_update_fn"] = shard_map(
                jax.vmap(gout_update_psum), mesh=mesh,
                in_specs=(gdev, gdev, gdev), out_specs=gcfg,
                check_rep=False)

        round_step = make_grid_round_step(
            model.apply, protocol=proto, num_devices=D,
            num_classes=C, local_iters=fc0.local_iters,
            local_batch=fc0.local_batch, server_batch=fc0.server_batch,
            t_max_slots=ch0.t_max_slots, tau_s=ch0.tau_s,
            dev_x=dev_x, dev_y=dev_y, test_x=jnp.asarray(test_x),
            test_y=jnp.asarray(test_y), consts=consts,
            per_config_data=per_config, codec=codec,
            cohort_size=Dc,
            arch_groups=(None if arch_models is None else
                         [(a, idx, m.apply) for a, idx, m in arch_models]),
            **fns)

        def _sweep_program(state, xs):
            engine_stats.traces += 1  # Python side effect: trace-counted
            return jax.lax.scan(round_step, state, xs)

        self._step_fn = jax.jit(_sweep_program)

        if arch_models is None:
            dev_params0 = jax.tree.map(
                lambda p: jnp.broadcast_to(
                    p[:, None], (G, D) + p.shape[1:]).copy(), g_params)
        else:
            # per-architecture (G, Da, ...) stacks; group 0 (= device 0 =
            # server architecture) broadcasts the global init
            dev_params0 = {}
            for a, idx, _ in arch_models:
                base = (g_params if a == arch_models[0][0] else
                        jax.tree.map(lambda *ls: jnp.stack(ls),
                                     *arch_inits[a]))
                dev_params0[a] = jax.tree.map(
                    lambda p: jnp.broadcast_to(
                        p[:, None], (G, len(idx)) + p.shape[1:]).copy(),
                    base)
        self._state0 = RoundState(
            dev_params=dev_params0,
            g_params=g_params,
            gout=jnp.full((G, C, C), 1.0 / C),
            dev_gout=jnp.full((G, D, C, C), 1.0 / C),
            prev=jnp.zeros((G, C * C if proto == "fd" else n_params)),
            converged_round=jnp.zeros((G,), jnp.int32),
            # host-loop fields ride as None in the grid layout
            round=None, key=None, seeds=None, cum_time_s=None)
        self._rp = GridRoundProgram(self._step_fn, self._state0,
                                    options=self.options)
        self.seed_sets = seed_sets if proto in FLD_FAMILY else None

    def run(self):
        """Execute the compiled scan through the :class:`GridRoundProgram`
        face; returns (final state, per-round outputs), outputs stacked
        (R, Gp)."""
        self._rp.step(self._state0, self._xs)
        return self._rp.finalize()


class SweepRunner:
    """Compiles one grid into at most one program per distinct protocol;
    ``run()`` re-executes the same compiled scans (warm calls skip
    tracing and compilation).  Heterogeneous grids (protocol and/or
    partition axes) and classic single-protocol shared-partition grids
    take the same entry point — for partitioned grids pass the *flat*
    sample pool as ``dev_x``/``dev_y`` and each point's
    :class:`PartitionSpec` splits it.

    Model/task-structural grids pass ``model=None``: each program group
    builds its architecture(s) from the model registry at the group's
    task shape, and grids with a ``task`` axis generate per-task
    procedural pools/test sets (``task_data``, auto-generated via
    :func:`make_task_data` when not given) instead of taking
    ``dev_x``/``test_x``."""

    def __init__(self, model, grid: SweepGrid, dev_x=None, dev_y=None,
                 test_x=None, test_y=None, *, task_data=None,
                 options: ProgramOptions | None = None):
        fc0, ch0 = grid.points[0]
        self.options = options or ProgramOptions()
        if ch0.num_devices != fc0.num_devices:
            raise ValueError(
                f"channel simulates {ch0.num_devices} links but the "
                f"population has {fc0.num_devices} devices")
        if model is not None and (
                grid.tasked
                or len({fc.model_key() for fc, _ in grid.points}) > 1
                or any(fc.model_partition is not None
                       for fc, _ in grid.points)):
            raise ValueError(
                "grids that sweep model/task axes (or run mixed-"
                "architecture cohorts) build their models from the "
                "registry; pass model=None")
        self.model = model
        self.grid = grid
        D, C = fc0.num_devices, fc0.num_classes

        if grid.tasked or task_data is not None:
            if dev_x is not None or dev_y is not None or \
                    test_x is not None or test_y is not None:
                raise ValueError(
                    "task-driven grids generate per-task pools and test "
                    "sets; pass dev_x/dev_y/test_x/test_y=None (supply "
                    "task_data=... to override the generated data)")
            if task_data is None:
                task_data = make_task_data(grid)
            self.task_data = task_data
            self.partitions = _resolve_task_partitions(grid, task_data)
        else:
            self.task_data = None
            self.partitions = _resolve_partitions(grid, dev_x, dev_y, D, C)

        self.mesh = (make_device_mesh(D, fc0.mesh_shards or None)
                     if fc0.shard_devices else None)

        memo = SeedPrepMemo()
        self._programs = []          # (protocol, idxs, program)
        for (proto, codec, csize, modelk, task), idxs in \
                grid.program_groups().items():
            fcg = grid.points[idxs[0]][0]
            gmodel, arch_models = _group_models(model, fcg)
            if self.task_data is not None:
                gtx, gty = self.task_data[task][2:4]
            else:
                gtx, gty = test_x, test_y
            prog = _ProtocolProgram(
                gmodel, grid, proto, idxs,
                [self.partitions[i] for i in idxs],
                gtx, gty, memo, self.mesh, codec=codec,
                cohort_size=csize, arch_models=arch_models,
                options=self.options)
            self._programs.append((proto, idxs, prog))
        self.programs = len(self._programs)

        self.seed_memo = memo
        fld_pts = [g for g, (fc, _) in enumerate(grid.points)
                   if fc.protocol in FLD_FAMILY]
        self.seed_prep_stats = {
            "groups": len({grid.seed_key(g) for g in fld_pts}),
            "prep_runs": memo.misses,
            "memo_hits": memo.hits,
        }
        if fld_pts:  # per-point seed sets in grid order (None at fl/fd
            # points of a mixed grid; dense for classic all-FLD grids)
            self.seed_sets = [None] * grid.size
            for _, idxs, prog in self._programs:
                if prog.seed_sets is not None:
                    for i, s in zip(idxs, prog.seed_sets):
                        self.seed_sets[i] = s
        else:
            self.seed_sets = None

    # ------------------------------------------------------------------
    def run(self) -> SweepResult:
        G, R = self.grid.size, self.grid.points[0][0].max_rounds
        acc = np.zeros((G, R), np.float32)
        loss = np.zeros((G, R), np.float32)
        latency = np.zeros((G, R), np.float64)
        up_ok = np.zeros((G, R), np.int32)
        converged = np.zeros((G,), np.int32)
        up_bits_first = np.zeros((G,), np.float64)
        up_bits = np.zeros((G,), np.float64)
        dp_epsilon = np.full((G,), np.nan)
        dp_epsilon_device = np.full((G,), np.nan)
        dp = [None] * G
        t0 = time.perf_counter()
        for proto, idxs, prog in self._programs:
            state, out = prog.run()
            rows = np.asarray(idxs)
            acc[rows] = out["acc"].T
            loss[rows] = out["loss"].T
            latency[rows] = out["latency_s"].T.astype(np.float64)
            up_ok[rows] = out["up_ok"].T
            converged[rows] = np.asarray(state["converged"])
            up_bits_first[rows] = prog.up_bits_first
            up_bits[rows] = prog.up_bits_steady
            dp_epsilon[rows] = prog.dp_epsilon
            dp_epsilon_device[rows] = prog.dp_epsilon_device
            for i, led in zip(idxs, prog.dp_ledgers):
                dp[i] = led
        wall = time.perf_counter() - t0
        return SweepResult(
            grid=self.grid, acc=acc, loss=loss, latency_s=latency,
            up_ok=up_ok, converged=converged, wall_s=wall,
            up_bits_first=up_bits_first, up_bits=up_bits,
            dp_epsilon=dp_epsilon, dp_epsilon_device=dp_epsilon_device,
            dp=tuple(dp))


def run_sweep(model, grid: SweepGrid, dev_x=None, dev_y=None, test_x=None,
              test_y=None, *, task_data=None,
              options: ProgramOptions | None = None) -> SweepResult:
    """One-shot convenience: build a :class:`SweepRunner` and run it."""
    return SweepRunner(model, grid, dev_x, dev_y, test_x, test_y,
                       task_data=task_data, options=options).run()


def run_pointwise(model, grid: SweepGrid, dev_x=None, dev_y=None,
                  test_x=None, test_y=None, log=None, *,
                  task_data=None) -> list[dict]:
    """The per-point loop the sweep replaces (and the equivalence oracle):
    one ``FederatedTrainer.run`` per grid point, re-tracing each time.
    Partitioned grids build each point's partition exactly like the
    runner, task-driven grids draw the same per-task pools/test sets, and
    ``model=None`` points build their (possibly mixed) architectures from
    the registry — so histories are comparable point-for-point."""
    fc0 = grid.points[0][0]
    if grid.tasked or task_data is not None:
        if task_data is None:
            task_data = make_task_data(grid)
        parts = _resolve_task_partitions(grid, task_data)
        tests = [task_data[fc.task][2:4] for fc, _ in grid.points]
    else:
        parts = _resolve_partitions(grid, dev_x, dev_y, fc0.num_devices,
                                    fc0.num_classes)
        tests = [(test_x, test_y)] * grid.size
    return [FederatedTrainer(model, fc, ch).run(px, py, tx, ty, log=log)
            for (fc, ch), (px, py), (tx, ty)
            in zip(grid.points, parts, tests)]
