"""The compiled protocol-sweep runner.

``SweepRunner`` turns a :class:`~repro.sweep.axes.SweepGrid` into ONE
jitted program: per-config constants (step sizes, conversion budgets,
link budgets, padded seed sets, PRNG keys) are stacked along a leading
grid axis G, the per-round protocol step from
``repro.core.protocols.make_grid_round_step`` is vmapped over that axis,
and ``jax.lax.scan`` drives it over rounds — so a grid of G configs ×
D devices × R rounds executes without returning to Python.  With
``shard_devices`` set on the base config, the device axis additionally
runs under ``shard_map`` on the 1-D "data" mesh (the same placement the
trainer uses), composing grid-vmap × device-sharding.

Everything the compiled program cannot express is absorbed host-side
*before* the scan, in exactly the per-point order the loop path uses:

* round-1 seed collection (sort-based pairing + cycle DFS) runs once per
  config via ``collect_seeds`` with the loop path's key chain, then pads
  the ragged train sets to the grid maximum (``n_train`` masks the
  `randint` draws onto the live prefix);
* conversion step keys are precomputed per (round, config) because
  ``jax.random.split`` is not prefix-stable across split counts;
* channel link budgets reduce to per-slot success probabilities and
  decode-slot counts (``round_slot_plan``), so traced draws stay
  bitwise-equal to the loop path.

The sweep-vs-loop equivalence tests (tests/test_sweep.py) assert the
whole per-round history matches ``FederatedTrainer.run`` per grid point.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.6 graduated shard_map out of experimental
    from jax import shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map

from ..channel import round_slot_plan
from ..core.protocols import (FLD_FAMILY, FederatedTrainer,
                              gout_update_psum, make_grid_local_train,
                              make_grid_round_step, weighted_avg_psum)
from ..core.seed_prep import SeedPrepMemo, prepare_seeds
from ..launch.mesh import make_device_mesh
from .axes import SweepGrid
from .results import SweepResult


def _pad_seed_sets(seed_sets, num_classes: int):
    """Stack ragged per-config train sets: (G, Nmax, ...) x, (G, Nmax[, C])
    y, (G,) live sizes.  Memoized seed prep hands grid points that share a
    seed key the *same* result object, so padding runs once per unique set
    and the stacked consts are fancy-indexed copies of those rows.  Mixed
    hard/soft grids (e.g. a ``lam`` axis that crosses 0.5) promote hard
    labels to one-hot rows — the conversion losses are identical for
    one-hot targets, so only mixed grids pay the (ulp-level) formulation
    change."""
    uniq_of: dict[int, int] = {}
    uniq, inv = [], []
    for s in seed_sets:
        u = uniq_of.get(id(s))
        if u is None:
            u = uniq_of[id(s)] = len(uniq)
            uniq.append(s)
        inv.append(u)
    xs = [np.asarray(s["train_x"]) for s in uniq]
    ys = [np.asarray(s["train_y"]) for s in uniq]
    n = np.asarray([x.shape[0] for x in xs], np.int32)
    n_max = int(n.max())
    feat = xs[0].shape[1:]
    px = np.zeros((len(xs), n_max) + feat, np.float32)
    for u, x in enumerate(xs):
        px[u, :x.shape[0]] = x
    hard = [y.ndim == 1 for y in ys]
    if all(hard):
        py = np.zeros((len(ys), n_max), np.int32)
        for u, y in enumerate(ys):
            py[u, :y.shape[0]] = y
    else:
        py = np.zeros((len(ys), n_max, num_classes), np.float32)
        for u, y in enumerate(ys):
            if y.ndim == 1:
                y = np.eye(num_classes, dtype=np.float32)[y]
            py[u, :y.shape[0]] = y
    inv = np.asarray(inv)
    return px[inv], py[inv], n[inv]


class SweepRunner:
    """Compiles one grid into one program; ``run()`` re-executes the same
    compiled scan (warm calls skip tracing and compilation)."""

    def __init__(self, model, grid: SweepGrid, dev_x, dev_y, test_x, test_y):
        fc0, ch0 = grid.points[0]
        if ch0.num_devices != fc0.num_devices:
            raise ValueError(
                f"channel simulates {ch0.num_devices} links but the "
                f"population has {fc0.num_devices} devices")
        self.model = model
        self.grid = grid
        self.proto = fc0.protocol
        G, D, C, R = grid.size, fc0.num_devices, fc0.num_classes, \
            fc0.max_rounds
        dev_x = jnp.asarray(dev_x)
        dev_y = jnp.asarray(dev_y)

        # ---- host prep, per config in the loop path's exact key order;
        # seed prep is memoized on the seed-determining content (an
        # eta-only or channel-only grid collects seeds exactly once and
        # every point of a seed group shares one result object) ----
        memo = SeedPrepMemo()
        run_keys, inits, conv_keys, seed_sets = [], [], [], []
        plans = {"p_up": [], "p_dn": [], "up1": [], "up": [], "dn": []}
        k_max = max(fc.server_iters for fc, _ in grid.points)
        for fc, ch in grid.points:
            kinit, key = jax.random.split(jax.random.PRNGKey(fc.seed))
            run_keys.append(np.asarray(key))
            params = self.model.init(kinit)
            inits.append(params)
            n_mod = sum(p.size for p in jax.tree.leaves(params))
            if self.proto in FLD_FAMILY:
                kr1 = jax.random.fold_in(key, 1)
                seed_sets.append(prepare_seeds(
                    fc, dev_x, dev_y, jax.random.fold_in(kr1, 2),
                    memo=memo))
                ck = np.zeros((R, k_max, 2), np.uint32)
                for p in range(1, R + 1):
                    base = jax.random.fold_in(jax.random.fold_in(key, p), 4)
                    ck[p - 1, :fc.server_iters] = np.asarray(
                        jax.random.split(base, fc.server_iters))
                conv_keys.append(ck)
            plan = round_slot_plan(
                self.proto, ch, n_mod=n_mod, n_labels=C,
                sample_bits=fc.sample_bits, n_seed=fc.n_seed)
            plans["p_up"].append(plan["p_up"])
            plans["p_dn"].append(plan["p_dn"])
            plans["up1"].append(plan["up_slots_first"])
            plans["up"].append(plan["up_slots"])
            plans["dn"].append(plan["dn_slots"])

        self.seed_memo = memo
        self.seed_prep_stats = {
            "groups": (len(grid.seed_groups())
                       if self.proto in FLD_FAMILY else 0),
            "prep_runs": memo.misses,
            "memo_hits": memo.hits,
        }

        g_params = jax.tree.map(lambda *ls: jnp.stack(ls), *inits)
        n_params = sum(p[0].size for p in jax.tree.leaves(g_params))

        consts = {
            "key": jnp.asarray(np.stack(run_keys)),
            "eta": jnp.asarray([fc.eta for fc, _ in grid.points],
                               jnp.float32),
            "beta": jnp.asarray([fc.beta for fc, _ in grid.points],
                                jnp.float32),
            "s_iters": jnp.asarray(
                [fc.server_iters for fc, _ in grid.points], jnp.int32),
            "eps": jnp.asarray([fc.eps for fc, _ in grid.points],
                               jnp.float32),
            "p_up": jnp.asarray(plans["p_up"], jnp.float32),
            "p_dn": jnp.asarray(plans["p_dn"], jnp.float32),
        }
        if self.proto in FLD_FAMILY:
            px, py, n_train = _pad_seed_sets(seed_sets, C)
            consts["seeds_x"] = jnp.asarray(px)
            consts["seeds_y"] = jnp.asarray(py)
            consts["n_train"] = jnp.asarray(n_train)
            ck = jnp.asarray(np.stack(conv_keys, axis=1))  # (R, G, Kmax, 2)
        else:
            consts["seeds_x"] = jnp.zeros((G, 1) + dev_x.shape[2:])
            consts["seeds_y"] = jnp.zeros((G, 1), jnp.int32)
            consts["n_train"] = jnp.ones((G,), jnp.int32)
            ck = jnp.zeros((R, G, 1, 2), jnp.uint32)

        up_slots = np.tile(np.asarray(plans["up"], np.int32), (R, 1))
        up_slots[0] = np.asarray(plans["up1"], np.int32)
        self._xs = {
            "p": jnp.arange(1, R + 1, dtype=jnp.int32),
            "up_slots": jnp.asarray(up_slots),
            "dn_slots": jnp.tile(jnp.asarray(plans["dn"], jnp.int32)[None],
                                 (R, 1)),
            "conv_keys": ck,
        }

        # ---- device-axis placement: vmapped, or shard_mapped over the
        # "data" mesh exactly like the trainer's sharded path ----
        fns = {}
        self.mesh = None
        if fc0.shard_devices:
            self.mesh = make_device_mesh(D, fc0.mesh_shards or None)
            grid_lt = make_grid_local_train(self.model.apply, C,
                                            fc0.local_iters, fc0.local_batch)
            gdev = P(None, "data")   # (G, D, ...): shard the device dim
            ddev = P("data")         # (D, ...) shared data
            rep = P()
            fns["local_train_fn"] = shard_map(
                grid_lt, mesh=self.mesh,
                in_specs=(gdev, ddev, ddev, gdev, gdev, rep, rep, rep),
                out_specs=(gdev, gdev, gdev, gdev), check_rep=False)
            fns["weighted_avg_fn"] = shard_map(
                jax.vmap(weighted_avg_psum), mesh=self.mesh,
                in_specs=(gdev, gdev), out_specs=rep, check_rep=False)
            fns["gout_update_fn"] = shard_map(
                jax.vmap(gout_update_psum), mesh=self.mesh,
                in_specs=(gdev, gdev, gdev), out_specs=rep,
                check_rep=False)

        round_step = make_grid_round_step(
            self.model.apply, protocol=self.proto, num_devices=D,
            num_classes=C, local_iters=fc0.local_iters,
            local_batch=fc0.local_batch, server_batch=fc0.server_batch,
            t_max_slots=ch0.t_max_slots, tau_s=ch0.tau_s,
            dev_x=dev_x, dev_y=dev_y, test_x=jnp.asarray(test_x),
            test_y=jnp.asarray(test_y), consts=consts, **fns)
        self._program = jax.jit(
            lambda state, xs: jax.lax.scan(round_step, state, xs))

        self._state0 = {
            "dev_params": jax.tree.map(
                lambda p: jnp.broadcast_to(
                    p[:, None], (G, D) + p.shape[1:]).copy(), g_params),
            "g_params": g_params,
            "gout": jnp.full((G, C, C), 1.0 / C),
            "dev_gout": jnp.full((G, D, C, C), 1.0 / C),
            "prev": jnp.zeros(
                (G, C * C if self.proto == "fd" else n_params)),
            "converged": jnp.zeros((G,), jnp.int32),
        }
        self.seed_sets = seed_sets if self.proto in FLD_FAMILY else None

    # ------------------------------------------------------------------
    def run(self) -> SweepResult:
        t0 = time.perf_counter()
        state, out = self._program(self._state0, self._xs)
        out = jax.tree.map(np.asarray, jax.block_until_ready(out))
        wall = time.perf_counter() - t0
        return SweepResult(
            grid=self.grid,
            acc=out["acc"].T, loss=out["loss"].T,          # (G, R)
            latency_s=out["latency_s"].T.astype(np.float64),
            up_ok=out["up_ok"].T,
            converged=np.asarray(state["converged"]),
            wall_s=wall)


def run_sweep(model, grid: SweepGrid, dev_x, dev_y, test_x, test_y
              ) -> SweepResult:
    """One-shot convenience: build a :class:`SweepRunner` and run it."""
    return SweepRunner(model, grid, dev_x, dev_y, test_x, test_y).run()


def run_pointwise(model, grid: SweepGrid, dev_x, dev_y, test_x, test_y,
                  log=None) -> list[dict]:
    """The per-point loop the sweep replaces (and the equivalence oracle):
    one ``FederatedTrainer.run`` per grid point, re-tracing each time."""
    return [FederatedTrainer(model, fc, ch).run(dev_x, dev_y, test_x,
                                                test_y, log=log)
            for fc, ch in grid.points]
