"""Vectorized protocol-sweep engine: whole hyperparameter grids as one
compiled program (vmap over configs × scan over rounds × [shard_map over
devices]).  See docs/sweep_engine.md."""
from .axes import CH_SWEEPABLE, FED_SWEEPABLE, SweepGrid, make_grid  # noqa: F401
from .engine import SweepRunner, run_pointwise, run_sweep  # noqa: F401
from .results import SweepResult  # noqa: F401
