"""Vectorized protocol-sweep engine: whole hyperparameter grids as few
compiled programs as the grid's structure allows (vmap over configs ×
scan over rounds × [shard_map over devices]; one program per distinct
protocol, per-config device partitions).  See docs/sweep_engine.md."""
from .axes import (ALL_SWEEPABLE, CH_SWEEPABLE, FED_SWEEPABLE,  # noqa: F401
                   GROUP_SWEEPABLE, PART_SWEEPABLE, SweepGrid, make_grid)
from .engine import (SweepRunner, engine_stats, make_task_data,  # noqa: F401
                     run_pointwise, run_sweep)
from .results import SweepResult  # noqa: F401
