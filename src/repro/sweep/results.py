"""Result frames for compiled protocol sweeps.

A :class:`SweepResult` holds the stacked per-round histories of every
grid point — (G, R) arrays — plus the wall-clock of the single compiled
execution that produced all of them.  ``history(g)`` reconstructs the
per-point dict shape ``FederatedTrainer.run`` returns (the equivalence
tests compare them field by field); ``frames()`` flattens the grid into
JSON-ready rows for the benchmark tables.

Timing semantics: channel latency is simulated per round per config
(``latency_s``), but compute wall-clock exists only for the sweep as a
whole — one program ran G configs at once.  ``cum_time_s`` therefore
amortizes the sweep's wall time evenly across configs and rounds, which
is the honest per-point cost of a batched run (and the number that makes
sweep rows comparable with loop-path rows in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SweepResult:
    grid: object                 # SweepGrid
    acc: np.ndarray              # (G, R)
    loss: np.ndarray             # (G, R)
    latency_s: np.ndarray        # (G, R)
    up_ok: np.ndarray            # (G, R) int
    converged: np.ndarray        # (G,) int32, 0 = never
    wall_s: float
    # per-point link accounting (codec-aware; None on results built by
    # older callers): uplink payload bits first/steady rounds, and the
    # cumulative DP epsilon after R rounds (NaN at non-DP points)
    up_bits_first: np.ndarray | None = None   # (G,)
    up_bits: np.ndarray | None = None         # (G,)
    dp_epsilon: np.ndarray | None = None      # (G,)
    # participation-aware DP: worst per-device epsilon (composed over
    # the rounds each device actually joined — equals dp_epsilon at
    # sample_ratio=1) and the full per-point accountant ledgers
    dp_epsilon_device: np.ndarray | None = None   # (G,)
    dp: tuple | None = None                       # (G,) ledger dict|None

    @property
    def rounds(self) -> int:
        return self.acc.shape[1]

    def cum_time_s(self, g: int) -> list[float]:
        """Cumulative latency + amortized compute for point ``g``."""
        per_round_compute = self.wall_s / (self.grid.size * self.rounds)
        lat = np.cumsum(self.latency_s[g])
        return list(lat + per_round_compute * np.arange(1, self.rounds + 1))

    def history(self, g: int) -> dict:
        """Per-point history in ``FederatedTrainer.run``'s shape (minus
        the host-only seeds/compute_s fields)."""
        h = {
            "acc": [float(a) for a in self.acc[g]],
            "loss": [float(l) for l in self.loss[g]],
            "round_latency_s": [float(t) for t in self.latency_s[g]],
            "uplink_ok": [int(u) for u in self.up_ok[g]],
            "cum_time_s": self.cum_time_s(g),
            "converged_round": (int(self.converged[g])
                                if self.converged[g] else None),
            "final_acc": float(self.acc[g, -1]),
            "protocol": self.grid.points[g][0].protocol,
            "model": self.grid.points[g][0].model_key(),
            "task": self.grid.points[g][0].task,
        }
        if self.dp is not None and self.dp[g] is not None:
            h["dp"] = self.dp[g]  # the loop path's history["dp"] ledger
        return h

    def uplink_bits_total(self, g: int) -> float | None:
        """Per-device uplink bits over the whole run: one first round +
        (R - 1) steady-state rounds of point ``g``."""
        if self.up_bits is None:
            return None
        return float(self.up_bits_first[g] +
                     (self.rounds - 1) * self.up_bits[g])

    def frames(self) -> list[dict]:
        """One JSON-ready row per grid point: axis values + summary."""
        rows = []
        for g, label in enumerate(self.grid.labels()):
            h = self.history(g)
            row = {
                "point": self.grid.point_name(g, label),
                **label,
                "final_acc": h["final_acc"],
                "cum_time_s": h["cum_time_s"][-1],
                "round1_latency_s": h["round_latency_s"][0],
                "converged_round": h["converged_round"],
                "acc": h["acc"],
            }
            if self.up_bits is not None:
                row["uplink_bits"] = float(self.up_bits[g])
                row["uplink_bits_total"] = self.uplink_bits_total(g)
                eps = float(self.dp_epsilon[g])
                # NaN -> None: non-DP points have no finite epsilon, and
                # the result payload stays strict-JSON serializable
                row["dp_epsilon"] = None if np.isnan(eps) else eps
                if self.dp_epsilon_device is not None:
                    dev = float(self.dp_epsilon_device[g])
                    row["dp_epsilon_device_max"] = (None if np.isnan(dev)
                                                    else dev)
            rows.append(row)
        return rows

    def to_payload(self) -> dict:
        """Whole-sweep JSON payload (grid axes + per-point frames).
        ``protocols`` lists the distinct protocols of the grid (one entry
        for classic homogeneous grids); ``protocol`` keeps the first
        point's protocol for backward compatibility."""
        protos = []
        for fc, _ in self.grid.points:
            if fc.protocol not in protos:
                protos.append(fc.protocol)
        return {
            "protocol": protos[0],
            "protocols": protos,
            "axes": {n: list(v) for n, v in self.grid.axes},
            "grid_shape": list(self.grid.shape),
            "wall_s": round(self.wall_s, 4),
            "points": self.frames(),
        }
