"""Sweep grids: which hyperparameters batch, and how points are built.

A :class:`SweepGrid` is the cartesian product of value lists over named
axes, rooted at one base ``FederatedConfig`` + ``ChannelConfig``.  Every
axis must be *sweepable*: a field whose variation the compiled sweep can
express as a traced per-config scalar (learning rates, KD weights, seed
budgets, conversion iterations, channel link budgets) or absorb host-side
before the program runs (``n_seed``/``n_inverse``/``lam`` change the
round-1 seed sets, ``seed`` the key chain, SNR fields the per-slot
success probabilities).  Fields that would change compiled *shapes or
control flow* across points — the protocol itself, population size,
local SGD geometry, round count, the fading window — are static: they
are taken from the base configs and shared by every point.
"""
from __future__ import annotations

import dataclasses
import itertools

from ..channel import ChannelConfig
from ..core.protocols import FederatedConfig
from ..core.seed_prep import seed_fields_key

# Traced per-config scalars, or host-absorbed before compilation.
FED_SWEEPABLE = frozenset({
    "eta", "beta", "eps", "lam", "n_seed", "n_inverse", "server_iters",
    "sample_bits", "seed",
})
# Channel fields only enter via the host-computed link budget
# (per-slot success probability + decode-slot counts), so any of them
# can sweep except the draw-shaping t_max_slots / num_devices / tau_s.
CH_SWEEPABLE = frozenset({
    "num_channels", "bandwidth_hz", "p_up_dbm", "p_dn_dbm", "distance_m",
    "pathloss_exp", "noise_dbm_hz", "theta",
})


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """A validated config grid: ``points[g]`` is the (fc, ch) pair of grid
    point g, in C-order (last axis fastest) over ``axes``."""
    base_fc: FederatedConfig
    base_ch: ChannelConfig
    axes: tuple[tuple[str, tuple], ...]   # ((name, values), ...)
    points: tuple

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(v) for _, v in self.axes)

    @property
    def size(self) -> int:
        return len(self.points)

    def labels(self) -> list[dict]:
        """Per-point {axis: value} dicts, aligned with ``points``."""
        names = [n for n, _ in self.axes]
        return [dict(zip(names, combo)) for combo in
                itertools.product(*(v for _, v in self.axes))]

    def point_name(self, g: int, label: dict | None = None) -> str:
        lab = label if label is not None else self.labels()[g]
        return "_".join(f"{k}{v}" for k, v in lab.items()) or f"pt{g}"

    def seed_key(self, g: int) -> tuple:
        """The seed-determining config fields of point ``g`` — points
        sharing it (and the partition, fixed per sweep) share one host
        seed-prep run (``core.seed_prep.seed_fields_key``)."""
        return seed_fields_key(self.points[g][0])

    def seed_groups(self) -> dict:
        """{seed_key: [point indices]} — e.g. an eta-only or channel-only
        grid is one group, so the runner collects seeds exactly once."""
        groups: dict = {}
        for g in range(self.size):
            groups.setdefault(self.seed_key(g), []).append(g)
        return groups


def make_grid(base_fc: FederatedConfig,
              base_ch: ChannelConfig | None = None, **axes) -> SweepGrid:
    """Build a :class:`SweepGrid` from a base config pair and keyword
    axes, e.g. ``make_grid(fc, ch, n_seed=(10, 50), eta=(0.01, 0.02))``.

    Raises ``ValueError`` for unknown or non-sweepable axis names and for
    empty value lists; axis order (= C-order of the grid) follows the
    keyword order.
    """
    base_ch = base_ch or ChannelConfig(num_devices=base_fc.num_devices)
    axes = {n: tuple(v) for n, v in axes.items()}  # once: generators exhaust
    for name, values in axes.items():
        if name not in FED_SWEEPABLE | CH_SWEEPABLE:
            fed_static = {f.name for f in dataclasses.fields(FederatedConfig)
                          } - FED_SWEEPABLE
            ch_static = {f.name for f in dataclasses.fields(ChannelConfig)
                         } - CH_SWEEPABLE
            kind = ("static (shape/control-flow) field"
                    if name in fed_static | ch_static else "unknown field")
            raise ValueError(
                f"axis {name!r} is a {kind}; sweepable axes: "
                f"{sorted(FED_SWEEPABLE)} + {sorted(CH_SWEEPABLE)}")
        if not values:
            raise ValueError(f"axis {name!r} has no values")

    items = tuple(axes.items())
    points = []
    for combo in itertools.product(*(v for _, v in items)):
        fc_kw, ch_kw = {}, {}
        for (name, _), value in zip(items, combo):
            (fc_kw if name in FED_SWEEPABLE else ch_kw)[name] = value
        points.append((dataclasses.replace(base_fc, **fc_kw),
                       dataclasses.replace(base_ch, **ch_kw)))
    return SweepGrid(base_fc=base_fc, base_ch=base_ch, axes=items,
                     points=tuple(points))
