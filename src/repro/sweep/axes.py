"""Sweep grids: which hyperparameters batch, and how points are built.

A :class:`SweepGrid` is the cartesian product of value lists over named
axes, rooted at one base ``FederatedConfig`` + ``ChannelConfig`` (+
optionally one base :class:`~repro.data.partition.PartitionSpec`).  Four
kinds of axis exist:

* **traced / host-absorbed config axes** (:data:`FED_SWEEPABLE`,
  :data:`CH_SWEEPABLE`) — fields whose variation the compiled sweep
  expresses as a per-config scalar or absorbs host-side (seed budgets,
  step sizes, SNR fields);
* **structural axes** (:data:`GROUP_SWEEPABLE`: ``protocol``,
  ``codec``) — protocols differ *structurally* (their round bodies
  branch) and so do link-codec families (identity skips the codec stage
  entirely; quantize/delta/dp_gaussian insert different transforms), so
  the engine groups points by (protocol, codec family, cohort size) and
  compiles one vmapped ``lax.scan`` program per distinct group — the
  cohort size joining because a ``sample_ratio`` axis changes the
  compiled device-axis shape.  A codec's *numeric* parameters
  (``quant_bits``, ``dp_sigma``, ``dp_clip``) and the ``sample_seed``
  are ordinary per-config values and batch inside a program;
* **partition axes** (:data:`PART_SWEEPABLE`: ``partition``, ``alpha``,
  ``n_local``) — which device partition a point trains on.  Each grid
  point carries a :class:`PartitionSpec`; the runner builds each
  *distinct* spec once, stacks the (possibly ragged) partitions along
  the grid axis, and routes seed prep through the content-keyed memo.

Fields that would change compiled shapes in ways the engine cannot pad
or group — population size, local SGD geometry, round count, the fading
window — stay static: they are taken from the base configs and shared by
every point.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

from ..channel import ChannelConfig
from ..channel.payload import CODECS, parse_codec
from ..core.protocols import FederatedConfig
from ..core.seed_prep import seed_fields_key
from ..data.partition import PARTITION_SCHEMES, PartitionSpec
from ..data.pipeline import parse_task
from ..models.registry import parse_model
# protocol names come from the one shared registry (the same module
# channel.payload and core.protocols validate against)
from ..registry import PROTOCOLS, canonical_protocol

# Traced per-config scalars, or host-absorbed before compilation.
# sample_ratio / sample_seed are host-absorbed: cohorts are precomputed
# per point and fed to the compiled scan as gather indices (ratios with
# equal cohort *size* batch in one program; see program_groups).
FED_SWEEPABLE = frozenset({
    "eta", "beta", "eps", "lam", "n_seed", "n_inverse", "server_iters",
    "sample_bits", "seed", "quant_bits", "dp_sigma", "dp_clip",
    "dp_delta", "sample_ratio", "sample_seed",
})
# Channel fields only enter via the host-computed link budget
# (per-slot success probability + decode-slot counts), so any of them
# can sweep except the draw-shaping t_max_slots / num_devices / tau_s.
CH_SWEEPABLE = frozenset({
    "num_channels", "bandwidth_hz", "p_up_dbm", "p_dn_dbm", "distance_m",
    "pathloss_exp", "noise_dbm_hz", "theta",
})
# Partition axes -> PartitionSpec fields: which device partition a grid
# point trains on (stacked per-config, ragged n_local padded + masked).
PART_SWEEPABLE = frozenset({"partition", "alpha", "n_local"})
_PART_FIELD = {"partition": "scheme", "alpha": "alpha", "n_local": "n_local"}
# Structural axes group points into stacked per-(protocol, codec family,
# cohort size, model, task) programs; all are FederatedConfig fields, so
# they route like FED axes.  ``model`` values may be composite
# ("cnn+mlp+transformer"): a mixed-architecture FD cohort per point.
# ``task`` changes input shapes and class counts, so tasked grids build
# per-task data pools and re-derive num_classes/sample_bits per point.
GROUP_SWEEPABLE = frozenset({"protocol", "codec", "model", "task"})

ALL_SWEEPABLE = FED_SWEEPABLE | CH_SWEEPABLE | PART_SWEEPABLE | \
    GROUP_SWEEPABLE


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """A validated config grid: ``points[g]`` is the (fc, ch) pair of grid
    point g, in C-order (last axis fastest) over ``axes``; ``parts[g]``
    is the point's :class:`PartitionSpec` (None for grids that take one
    pre-partitioned dataset)."""
    base_fc: FederatedConfig
    base_ch: ChannelConfig
    axes: tuple[tuple[str, tuple], ...]   # ((name, values), ...)
    points: tuple
    parts: tuple = ()                     # per-point PartitionSpec | None
    base_part: Optional[PartitionSpec] = None

    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(len(v) for _, v in self.axes)

    @property
    def size(self) -> int:
        return len(self.points)

    @property
    def partitioned(self) -> bool:
        """True iff every point names its own device partition (the
        runner then takes a flat sample pool, not (D, n_local) data)."""
        return bool(self.parts) and self.parts[0] is not None

    def labels(self) -> list[dict]:
        """Per-point {axis: value} dicts, aligned with ``points``."""
        names = [n for n, _ in self.axes]
        return [dict(zip(names, combo)) for combo in
                itertools.product(*(v for _, v in self.axes))]

    def point_name(self, g: int, label: dict | None = None) -> str:
        lab = label if label is not None else self.labels()[g]
        return "_".join(f"{k}{v}" for k, v in lab.items()) or f"pt{g}"

    def seed_key(self, g: int) -> tuple:
        """The seed-determining identity of point ``g``: config fields
        plus the partition spec it trains on — points sharing it share
        one host seed-prep run (``core.seed_prep.seed_fields_key``; the
        partition's *content* is additionally fingerprinted by the memo)."""
        return (seed_fields_key(self.points[g][0]),
                self.parts[g] if self.parts else None)

    def seed_groups(self) -> dict:
        """{seed_key: [point indices]} — e.g. an eta-only or channel-only
        grid is one group, so the runner collects seeds exactly once."""
        groups: dict = {}
        for g in range(self.size):
            groups.setdefault(self.seed_key(g), []).append(g)
        return groups

    def protocol_groups(self) -> dict:
        """{protocol: [point indices]} in point order — one compiled
        program per key (protocols differ structurally, so they cannot
        share a round body; everything else batches inside a group)."""
        groups: dict = {}
        for g, (fc, _) in enumerate(self.points):
            groups.setdefault(fc.protocol, []).append(g)
        return groups

    def program_groups(self) -> dict:
        """{(protocol, codec family, cohort size, model, task): [point
        indices]} in point order — the engine's compilation unit.  The
        codec *family* is structural (it changes which transforms the
        round body contains); its numeric parameters stay traced, so
        e.g. a ``quant_bits`` axis batches inside one quantize program.
        The *cohort size* is structural too (it fixes the device-axis
        shape of the compiled round); ``sample_ratio=1.0`` points
        resolve to the full pool and compile graph-identical programs to
        the unsampled step, while a ``sample_seed`` axis — same size,
        different draws — batches inside one sampled program.  The
        *model* (the full per-device assignment for mixed cohorts) and
        *task* fix the parameter pytrees and input shapes, so each
        distinct architecture/workload pair is its own program —
        exactly like the protocol grouping."""
        groups: dict = {}
        for g, (fc, _) in enumerate(self.points):
            key = (fc.protocol, fc.codec_spec().name, fc.cohort_size(),
                   fc.model_key(), fc.task)
            groups.setdefault(key, []).append(g)
        return groups

    def task_groups(self) -> dict:
        """{task name: [point indices]} in point order — the unit the
        runner materializes one data pool (and test set) for."""
        groups: dict = {}
        for g, (fc, _) in enumerate(self.points):
            groups.setdefault(fc.task, []).append(g)
        return groups

    @property
    def tasked(self) -> bool:
        """True iff the grid sweeps the ``task`` axis (the runner then
        generates one procedural pool per task instead of taking data)."""
        return any(n == "task" for n, _ in self.axes)


def _validate_axis(name: str, values: tuple):
    if name not in ALL_SWEEPABLE:
        fed_static = {f.name for f in dataclasses.fields(FederatedConfig)
                      } - FED_SWEEPABLE - GROUP_SWEEPABLE
        ch_static = {f.name for f in dataclasses.fields(ChannelConfig)
                     } - CH_SWEEPABLE
        kind = ("static (shape/control-flow) field"
                if name in fed_static | ch_static else "unknown field")
        raise ValueError(
            f"axis {name!r} is a {kind}; sweepable axes: "
            f"{sorted(FED_SWEEPABLE)} + {sorted(CH_SWEEPABLE)} + "
            f"{sorted(PART_SWEEPABLE)} + {sorted(GROUP_SWEEPABLE)}")
    if not values:
        raise ValueError(f"axis {name!r} has no values")
    if name == "protocol":
        for v in values:
            try:
                canonical_protocol(v)
            except ValueError as e:
                # the one shared registry message, prefixed with the axis
                raise ValueError(
                    f"protocol axis value {v!r} is not a registered "
                    f"protocol: {e}") from None
    if name == "codec":
        for v in values:
            try:
                parse_codec(v)
            except ValueError as e:
                raise ValueError(
                    f"codec axis value {v!r} is not a registered codec: "
                    f"{e} (families: {CODECS})") from None
    if name == "partition":
        for v in values:
            if v not in PARTITION_SCHEMES:
                raise ValueError(
                    f"partition axis value {v!r} is not a registered "
                    f"partition scheme; one of {PARTITION_SCHEMES}")
    if name == "model":
        for v in values:
            try:
                parse_model(v)
            except ValueError as e:
                raise ValueError(
                    f"model axis value {v!r} is not a registered model "
                    f"spec: {e}") from None
    if name == "task":
        for v in values:
            try:
                parse_task(v)
            except ValueError as e:
                raise ValueError(
                    f"task axis value {v!r} is not a registered task: "
                    f"{e}") from None


def make_grid(base_fc: FederatedConfig,
              base_ch: ChannelConfig | None = None,
              base_part: PartitionSpec | None = None, **axes) -> SweepGrid:
    """Build a :class:`SweepGrid` from a base config pair and keyword
    axes, e.g. ``make_grid(fc, ch, n_seed=(10, 50), eta=(0.01, 0.02))``
    or, heterogeneously,
    ``make_grid(fc, ch, protocol=("fl", "mix2fld"),
    partition=("iid", "noniid"))``.

    Raises ``ValueError`` for unknown or non-sweepable axis names, for
    empty value lists, and for unregistered ``protocol`` / ``partition``
    axis values; axis order (= C-order of the grid) follows the keyword
    order.  Grids with partition axes (or an explicit ``base_part``)
    carry a :class:`PartitionSpec` per point; their runner takes the flat
    sample pool instead of pre-partitioned (D, n_local) data.
    """
    base_ch = base_ch or ChannelConfig(num_devices=base_fc.num_devices)
    axes = {n: tuple(v) for n, v in axes.items()}  # once: generators exhaust
    for name, values in axes.items():
        _validate_axis(name, values)

    # a task axis changes input shapes and data pools, so tasked grids
    # are always partitioned: the runner builds each task's pool and
    # cuts it per point's PartitionSpec
    partitioned = base_part is not None or any(
        n in PART_SWEEPABLE or n == "task" for n in axes)
    base_part = base_part or (PartitionSpec() if partitioned else None)

    items = tuple(axes.items())
    points, parts = [], []
    for combo in itertools.product(*(v for _, v in items)):
        fc_kw, ch_kw, pt_kw = {}, {}, {}
        for (name, _), value in zip(items, combo):
            if name in CH_SWEEPABLE:
                ch_kw[name] = value
            elif name in PART_SWEEPABLE:
                pt_kw[_PART_FIELD[name]] = value
            else:  # FED_SWEEPABLE | GROUP_SWEEPABLE: FederatedConfig fields
                fc_kw[name] = value
        if "task" in fc_kw:
            # re-derive the task-dependent fields per point (an explicit
            # sample_bits axis still wins); num_classes follows the task
            fc_kw["num_classes"] = None
            fc_kw.setdefault("sample_bits", None)
        if "model" in fc_kw:
            # never carry a stale per-device assignment across the axis
            fc_kw["model_partition"] = None
        points.append((dataclasses.replace(base_fc, **fc_kw),
                       dataclasses.replace(base_ch, **ch_kw)))
        parts.append(dataclasses.replace(base_part, **pt_kw)
                     if partitioned else None)
    return SweepGrid(base_fc=base_fc, base_ch=base_ch, axes=items,
                     points=tuple(points), parts=tuple(parts),
                     base_part=base_part)
