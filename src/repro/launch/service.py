"""Continuous-serving federated round driver: train forever, survive
SIGKILL, answer inference traffic between rounds.

``FederatedTrainer.run`` is a terminate-and-exit script; this module
drives the same factored round step (:meth:`FederatedTrainer.round_once`)
as a long-running service:

* **Churn** — devices arrive and depart between rounds.  The active
  cohort of round ``p`` is drawn by a *stateless* seeded host process
  (``np.random.default_rng([fc.seed, churn.seed, p, MECH_CHURN])`` —
  the mechanism tag keeps churn's stream disjoint from the client
  sampler's), so the cohort sequence is a pure function of the round
  number: a resumed run draws the exact cohorts the uninterrupted run
  would have, with no RNG state to checkpoint.
* **Straggler timeouts** — enabled through the channel config
  (``compute_mean_s``/``deadline_s``): the :class:`LinkPlan` draw masks
  devices past the round deadline out of the aggregation set exactly
  like uplink outages (see ``channel.pipeline``).
* **Checkpoint/restore** — every ``ckpt_every`` rounds the full
  resumable state (round PRNG key, global + per-device params,
  ``gout``/``dev_gout``, the convergence reference, the round-1 seed
  set) goes through the crash-safe ``checkpoint`` package, with the
  host-side scalars (round counter, cumulative time, converged round,
  DP accountant position, per-round history) in the manifest ``meta``.
  A SIGKILLed run restores from the latest complete step directory and
  continues the *bit-identical* PRNG stream: every per-round draw
  derives from ``fold_in(key, p)``, and both ``key`` and ``p`` are in
  the checkpoint.
* **Batched inference** — :class:`InferenceEndpoint` serves the current
  global model between rounds with a fixed-batch jitted apply (the CNN
  single-shot analogue of ``launch.serve``'s prefill step: one compiled
  shape, requests padded to it, so serving never retraces).

With churn and stragglers disabled the per-round records equal
``FederatedTrainer.run``'s history bit-for-bit — locked down in
tests/test_service.py.

CLI smoke (checkpoint + kill + resume + one served batch)::

    PYTHONPATH=src python -m repro.launch.service --rounds 4 \
        --ckpt-dir /tmp/fedsvc --verify-resume
"""
from __future__ import annotations

import argparse
import tempfile
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.channel import ChannelConfig
from repro.core.privacy import GaussianAccountant
from repro.core.program import LoopRoundProgram, ProgramOptions
from repro.core.protocols import (FederatedConfig, FederatedTrainer,
                                  summarize_seeds)
from repro.core.sampling import ChurnConfig
from repro.core.state import RoundState

__all__ = ["ChurnConfig", "FederatedService", "InferenceEndpoint"]

#: Keys of one round's JSON-ready history record (the ``link`` arrays
#: stay out of the checkpoint meta).
_RECORD_KEYS = ("round", "acc", "loss", "round_latency_s", "compute_s",
                "cum_time_s", "uplink_ok", "n_straggle")


class InferenceEndpoint:
    """Fixed-batch jitted inference over the current global model.

    The serving shape mirrors ``launch.serve``: one compiled step at a
    fixed batch size (the prefill analogue — the CNN is single-shot, so
    there is no decode loop), with incoming requests queued and padded
    to that shape.  ``submit`` enqueues feature arrays; ``flush`` runs
    as many padded batches as the queue holds and returns per-request
    predicted labels in submission order.

    ``input_shape`` (normally the serving task's
    ``TaskSpec.input_shape``) pins the per-request feature shape; a
    mis-shaped request is rejected at ``submit`` time with both sides
    named, instead of surfacing as a retrace or a model-side shape
    error mid-flush.
    """

    def __init__(self, apply_fn, batch_size: int = 16,
                 input_shape: Optional[tuple] = None):
        self.batch_size = batch_size
        self.input_shape = tuple(input_shape) if input_shape else None
        self._queue: list = []
        self.served = 0
        self.batches = 0

        def predict(params, x):
            return jnp.argmax(apply_fn(params, x), axis=-1)

        self._predict = jax.jit(predict)

    def submit(self, x) -> int:
        """Queue a request batch ``(n, ...)``; returns n."""
        x = np.asarray(x)
        if self.input_shape is not None and \
                tuple(x.shape[1:]) != self.input_shape:
            raise ValueError(
                f"endpoint serves a model built for input shape "
                f"{self.input_shape} but got a request batch of shape "
                f"{tuple(x.shape[1:])}")
        self._queue.extend(x)
        return x.shape[0]

    @property
    def pending(self) -> int:
        return len(self._queue)

    def flush(self, g_params) -> np.ndarray:
        """Serve every pending request against ``g_params``.  Requests
        are padded to the fixed batch shape (pad rows are discarded), so
        the jitted step never retraces.

        Failure-safe: results only reach the caller if every chunk
        predicts, so if predict raises mid-loop NO request was answered
        — the whole flushed queue is re-queued (ahead of anything
        submitted meanwhile) before the exception propagates.  A
        crashed flush loses no requests: the next flush serves them
        all, in submission order.  (Re-queueing only the unreached tail
        here used to leak the already-predicted chunks — their results
        never left this frame.)"""
        if not self._queue:
            return np.zeros((0,), np.int32)
        out = []
        B = self.batch_size
        queue, self._queue = self._queue, []
        try:
            for i in range(0, len(queue), B):
                chunk = np.stack(queue[i:i + B])
                n = chunk.shape[0]
                if n < B:
                    pad = np.zeros((B - n,) + chunk.shape[1:],
                                   chunk.dtype)
                    chunk = np.concatenate([chunk, pad])
                preds = np.asarray(self._predict(g_params,
                                                 jnp.asarray(chunk)))[:n]
                out.append(preds)
                self.batches += 1
        except BaseException:
            self._queue[:0] = queue
            raise
        preds = np.concatenate(out)
        self.served += preds.shape[0]
        return preds


class FederatedService:
    """Crash-safe continuous round driver over a device pool.

    ``pool_x``/``pool_y`` are the *full* population's shards
    ``(P, n_local, ...)``; each round trains the churned active cohort
    through :meth:`FederatedTrainer.round_once` and scatters the
    cohort's updated device state back into the pool.  ``step()`` runs
    one round; :meth:`run_rounds` drives N of them with periodic
    checkpoints; :meth:`restore` resumes from the newest complete
    checkpoint in ``ckpt_dir``.
    """

    def __init__(self, model, fc: FederatedConfig,
                 ch: Optional[ChannelConfig] = None, *,
                 churn: Optional[ChurnConfig] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 1,
                 keep: Optional[int] = None, serve_batch: int = 16,
                 options: Optional[ProgramOptions] = None):
        if fc.model_partition is not None:
            raise ValueError(
                "FederatedService drives homogeneous cohorts: churn "
                "gathers/scatters one (P, ...) device stack, which a "
                "mixed-architecture cohort's per-architecture stacks "
                "don't fit; run mixed cohorts through FederatedTrainer "
                "or the sweep engine")
        self.trainer = FederatedTrainer(model, fc, ch)
        self.fc = self.trainer.fc
        # explicit churn wins, then the config's own churn sub-config
        self.churn = churn or self.fc.churn or ChurnConfig()
        self.options = options or ProgramOptions()
        # the unified round program: at pipeline_depth > 1 future rounds'
        # link draws are dispatched while the current round trains; the
        # per-round plan rides in xs, so a churn-driven cohort-size
        # change invalidates (and cheaply re-draws) stale handles
        self._program = LoopRoundProgram(self.trainer, self.options)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        # the served batch shape comes from the config's task, so a
        # model=None service (registry-built) serves the right geometry
        self.endpoint = InferenceEndpoint(
            self.trainer.model.apply, serve_batch,
            input_shape=self.fc.task_spec().input_shape)
        spec = self.fc.codec_spec()
        # effective participation fraction: churn and client sampling
        # compose (round_once sub-samples the churned cohort)
        q = self.churn.p_active * self.fc.sample_ratio
        self._acct = (GaussianAccountant(spec.dp_sigma, spec.dp_delta,
                                         sample_ratio=q)
                      if spec.name == "dp_gaussian" else None)
        self.state = self.trainer.init_state()
        self.history: list[dict] = []
        self._data = None
        self._seed_meta = None  # summarize_seeds of the round-1 set

    # -- data binding --------------------------------------------------
    def bind_data(self, pool_x, pool_y, test_x, test_y):
        """Attach the device pool and eval set (kept out of checkpoints:
        data re-binds on process start, state restores from disk)."""
        pool_x, pool_y = jnp.asarray(pool_x), jnp.asarray(pool_y)
        if pool_x.shape[0] != self.fc.num_devices:
            raise ValueError(
                f"pool has {pool_x.shape[0]} devices but the config "
                f"says num_devices={self.fc.num_devices}")
        self._data = (pool_x, pool_y, jnp.asarray(test_x),
                      jnp.asarray(test_y))
        return self

    # -- one round -----------------------------------------------------
    def step(self, log=None) -> dict:
        """One federated round over the churned active cohort; returns
        the round record (plus cohort bookkeeping)."""
        if self._data is None:
            raise RuntimeError("call bind_data(...) before step()")
        pool_x, pool_y, test_x, test_y = self._data
        state = RoundState.from_mapping(self.state)
        p = state.round + 1
        idx = self.churn.active_devices(self.fc.seed, p,
                                        self.fc.num_devices)
        jdx = jnp.asarray(idx)
        cohort = state.replace(
            dev_params=jax.tree.map(lambda a: a[jdx], state.dev_params),
            dev_gout=state.dev_gout[jdx])
        plan = self.trainer.link_plan(state.g_params, n_links=len(idx))
        cohort, rec = self._program.step(
            cohort, {"dev_x": pool_x[jdx], "dev_y": pool_y[jdx],
                     "test_x": test_x, "test_y": test_y, "plan": plan,
                     "log": log})
        # scatter the cohort's device state back into the pool; shared
        # (global) fields carry over wholesale
        self.state = cohort.replace(
            dev_params=jax.tree.map(
                lambda pool, coh: pool.at[jdx].set(coh),
                state.dev_params, cohort.dev_params),
            dev_gout=state.dev_gout.at[jdx].set(cohort.dev_gout))
        # actual participants: the churned cohort, further narrowed by
        # round_once's client sampling when fc.sample_ratio < 1
        # (rec["cohort"] indexes within the churned cohort)
        active = idx if rec["cohort"] is None else idx[rec["cohort"]]
        if self._acct is not None:
            # privacy budget is spent by participating devices only
            self._acct.step(cohort=active)
            rec["dp_epsilon"] = self._acct.epsilon()
            rec["dp_epsilon_device_max"] = self._acct.epsilon_device_max()
        rec["n_active"] = len(active)
        rec["active"] = active
        self.history.append(rec)
        if self.ckpt_dir and p % self.ckpt_every == 0:
            self.save_checkpoint()
        return rec

    def run_rounds(self, n: int, log=None) -> list[dict]:
        """Drive ``n`` rounds (the CLI's --rounds; a real deployment
        loops step() forever)."""
        return [self.step(log=log) for _ in range(n)]

    # -- serving -------------------------------------------------------
    def serve(self, x) -> np.ndarray:
        """Answer one inference request batch against the current
        global model (between rounds, training state untouched)."""
        self.endpoint.submit(x)
        return self.endpoint.flush(self.state.g_params)

    # -- checkpoint / restore -----------------------------------------
    def _history_meta(self) -> list[dict]:
        return [{k: r.get(k) for k in
                 _RECORD_KEYS + ("n_active", "dp_epsilon",
                                 "dp_epsilon_device_max")
                 if k in r} for r in self.history]

    def save_checkpoint(self) -> str:
        """Write the full resumable state.  Array state goes in the
        (atomically renamed) step dir; host scalars ride in the manifest
        meta.  ``prev`` is absent only before the first round."""
        if not self.ckpt_dir:
            raise RuntimeError("service has no ckpt_dir")
        state = RoundState.from_mapping(self.state)
        tree = {"key": np.asarray(state.key),
                "g_params": state.g_params,
                "dev_params": state.dev_params,
                "gout": state.gout,
                "dev_gout": state.dev_gout}
        if state.prev is not None:
            tree["prev"] = state.prev
        if state.seeds is not None:
            tree["seeds"] = {"train_x": state.seeds["train_x"],
                             "train_y": state.seeds["train_y"]}
        if self._seed_meta is None and state.seeds is not None \
                and "uploaded" in state.seeds:
            # the full round-1 dict is only in memory on the run that
            # collected it; its summary rides along in every checkpoint
            self._seed_meta = summarize_seeds(state.seeds)
        meta = {"round": state.round,
                "cum_time_s": state.cum_time_s,
                "converged_round": state.converged_round,
                "protocol": self.fc.protocol,
                "dp_rounds": (self._acct.rounds
                              if self._acct is not None else 0),
                # dense per-device participation counts as a flat int
                # list — compact at pool scale, unlike a str-keyed dict
                "dp_device_counts": (
                    self._acct.device_counts.tolist()
                    if self._acct is not None else None),
                "seed_meta": self._seed_meta,
                "history": self._history_meta()}
        return checkpoint.save(self.ckpt_dir, state.round, tree,
                               meta=meta, keep=self.keep)

    def restore(self, step: Optional[int] = None) -> int:
        """Rebuild the resumable state from the newest (or ``step``-th)
        checkpoint; returns the restored round number.  Bit-identical
        continuation: the round key and counter come straight off disk,
        and every in-round draw is derived from them."""
        if not self.ckpt_dir:
            raise RuntimeError("service has no ckpt_dir")
        tree, meta = checkpoint.restore_tree(self.ckpt_dir, step)
        seeds = None
        if "seeds" in tree:
            seeds = {"train_x": jnp.asarray(tree["seeds"]["train_x"]),
                     "train_y": jnp.asarray(tree["seeds"]["train_y"])}
        # checkpoint manifest keys ARE RoundState fields (1:1); the
        # array tree holds the device-resident fields, the manifest meta
        # the host scalars
        self.state = RoundState(
            round=meta["round"],
            key=jnp.asarray(tree["key"]),
            g_params=jax.tree.map(jnp.asarray, tree["g_params"]),
            dev_params=jax.tree.map(jnp.asarray, tree["dev_params"]),
            gout=jnp.asarray(tree["gout"]),
            dev_gout=jnp.asarray(tree["dev_gout"]),
            prev=(jnp.asarray(tree["prev"]) if "prev" in tree
                  else None),
            converged_round=meta["converged_round"],
            seeds=seeds,
            cum_time_s=meta["cum_time_s"],
        )
        self.history = list(meta.get("history", []))
        # draws dispatched before the restore point are stale (they were
        # keyed off rounds this process will now re-run with possibly
        # different cohort plans) — drop the whole window; re-drawing is
        # cheap and the keys are pure functions of (key, round) anyway
        self._program.finalize()
        self._seed_meta = meta.get("seed_meta")
        if self._acct is not None:
            self._acct.rounds = meta.get("dp_rounds", 0)
            counts = meta.get("dp_device_counts")
            if counts is not None:
                self._acct.device_counts = np.asarray(counts, np.int64)
            else:
                # pre-array checkpoints stored a str-keyed dict
                self._acct.device_rounds = {
                    int(k): int(v) for k, v in
                    (meta.get("dp_device_rounds") or {}).items()}
        return meta["round"]


# ---------------------------------------------------------------------------
# CLI smoke: N rounds with checkpoints, one served batch, optional
# kill-free resume verification (restore an earlier step, re-run the
# tail, compare records) — the CI sweeps job runs this.
# ---------------------------------------------------------------------------

def _smoke_setup(args):
    from repro.data import partition_iid
    from repro.data.pipeline import parse_task

    # the task fixes data geometry and class count; the model comes from
    # the registry (defaults reproduce the historical CNN-on-digits
    # smoke bit-for-bit: same generator, same init stream)
    task = parse_task(getattr(args, "task", "digits"))
    x, y = task.data(jax.random.PRNGKey(42), 1400)
    dev_x, dev_y = partition_iid(np.asarray(x[:1200]),
                                 np.asarray(y[:1200]), 4, 300,
                                 task.num_classes, seed=0)
    fc = FederatedConfig(protocol=args.protocol, num_devices=4,
                         local_iters=8, local_batch=16, server_iters=8,
                         server_batch=16, max_rounds=args.rounds,
                         n_seed=6, n_inverse=12, seed=0,
                         model=getattr(args, "model", "cnn"),
                         task=task.name)
    ch = ChannelConfig(num_devices=4, p_up_dbm=40.0,
                       compute_mean_s=args.compute_mean_s,
                       deadline_s=args.deadline_s)
    churn = ChurnConfig(p_active=args.p_active, min_active=2)
    opts = ProgramOptions(
        pipeline_depth=getattr(args, "pipeline_depth", 1))
    svc = FederatedService(None, fc, ch, churn=churn,
                           ckpt_dir=args.ckpt_dir, ckpt_every=1,
                           options=opts)
    svc.bind_data(dev_x, dev_y, x[1200:], y[1200:])
    return svc, (x, y)


def _tail(records):
    return [{k: r[k] for k in ("round", "acc", "loss", "round_latency_s",
                               "uplink_ok")} for r in records]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="continuous federated service smoke")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--protocol", default="mix2fld")
    ap.add_argument("--model", default="cnn",
                    help="registry model to train/serve (cnn/mlp/"
                         "transformer; homogeneous only)")
    ap.add_argument("--task", default="digits",
                    help="registry task shaping the synthetic workload")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--p-active", type=float, default=0.75)
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    dest="pipeline_depth",
                    help="rounds of link draws in flight (1 = strict "
                         "serial; 2 = double-buffered channel sim)")
    ap.add_argument("--compute-mean-s", type=float, default=0.05,
                    dest="compute_mean_s")
    ap.add_argument("--deadline-s", type=float, default=0.15,
                    dest="deadline_s")
    ap.add_argument("--verify-resume", action="store_true",
                    help="restore the halfway checkpoint into a fresh "
                         "service, re-run the tail, and require "
                         "identical per-round records")
    args = ap.parse_args(argv)
    if args.ckpt_dir is None:
        args.ckpt_dir = tempfile.mkdtemp(prefix="fedsvc_")

    svc, _ = _smoke_setup(args)
    recs = svc.run_rounds(args.rounds, log=print)
    n_straggled = sum(r["n_straggle"] for r in recs)
    pstats = svc._program.finalize()
    print(f"trained {args.rounds} rounds: final acc={recs[-1]['acc']:.3f}"
          f" cohort sizes={[r['n_active'] for r in recs]}"
          f" stragglers dropped={n_straggled}"
          f" pipeline={pstats}")

    # one served batch against the live global model
    pool_x = np.asarray(svc._data[0])
    preds = svc.serve(pool_x[0][: svc.endpoint.batch_size])
    print(f"served {preds.shape[0]} predictions "
          f"(endpoint batches={svc.endpoint.batches})")

    if args.verify_resume:
        mid = max(1, args.rounds // 2)
        svc2, _ = _smoke_setup(args)
        got = svc2.restore(step=mid)
        assert got == mid, (got, mid)
        tail = svc2.run_rounds(args.rounds - mid)
        want, have = _tail(recs[mid:]), _tail(tail)
        if want != have:
            print(f"RESUME MISMATCH:\n  want {want}\n  have {have}")
            return 1
        print(f"resume verified: rounds {mid + 1}..{args.rounds} "
              f"bit-identical after restore from step {mid}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
