"""Per-architecture sharding policy (TP + FSDP + EP).

Conventions (axes: optional "pod", "data", "model"):
  * big weight matrices are 2-D sharded: input-ish dim over "data" (FSDP),
    output-ish/head/expert dim over "model" (TP);
  * experts shard over "model" when divisible, else the expert FFN dim;
  * batch shards over ("pod", "data");
  * decode KV caches: batch over ("pod","data"), *sequence over "model"*
    (sequence-parallel decode attention: scores reduce over the sharded
    key axis, emitting one tiny all-reduce per layer instead of gathering
    the multi-GB cache);
  * SSM caches: heads over "model".

Weight rules are path-based over the param pytree, so they apply to every
family without per-arch tables.  GSPMD tolerates non-divisible dims by
padding; rules below avoid any padding worse than 2x.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# ---------------------------------------------------------------------------
# Federated round-loop rules (device axis)
# ---------------------------------------------------------------------------

def federated_pspecs():
    """PartitionSpecs for the shard_mapped federated round loop over a 1-D
    ("data",) mesh (launch.mesh.make_device_mesh): ``device`` shards the
    leading device axis of every per-device operand (stacked params,
    local datasets, per-round PRNG keys, per-device G_out tables),
    ``replicated`` covers scalars and the aggregated tables the psum
    collectives return on every shard."""
    return {"device": P("data"), "replicated": P()}


def federated_grid_pspecs():
    """PartitionSpecs for the pod-scale sweep program over the 2-D
    ("grid", "data") mesh (launch.mesh.make_grid_mesh):

    * ``gdev`` — (G, D, ...) operands: grid axis over "grid", federated
      device axis over "data" (stacked params, per-point datasets,
      per-device keys and G_out tables);
    * ``gcfg`` — (G, ...) per-config constants and outputs (etas, link
      budgets, per-round metrics): grid axis only, whole per-point
      value on each "data" shard;
    * ``data`` — (D, ...) operands shared across grid points (a common
      dataset partition): device axis only, replicated over "grid";
    * ``replicated`` — true scalars (the round counter).

    The device-axis reductions stay psums over "data" exactly as on the
    1-D mesh — each grid shard's psum spans only its own rows, which is
    precisely that grid point's aggregation set, so no "grid"
    collectives exist anywhere (grid points are independent programs
    that happen to share one compiled body)."""
    return {"gdev": P("grid", "data"), "gcfg": P("grid"),
            "data": P("data"), "replicated": P()}


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

def _rule_for(cfg, mesh, path: str, ndim: int, shape):
    """Returns a PartitionSpec for a (non-stacked) parameter."""
    tp = _axis_size(mesh, "model")
    last = path.split("/")[-1]

    # --- norms / scalars / small vectors: replicate
    if last in ("scale", "bias", "A_log", "D", "dt_bias", "norm", "q_norm",
                "kv_norm", "q_scale", "k_scale", "bq", "bk", "bv"):
        return P()
    if last == "pos_embed":
        return P(None, "data")
    if last == "embed":  # (V, D)
        return P("model", "data")
    if last == "unembed":  # (D, V)
        return P("data", "model")
    if last == "router":  # (D, E) — tiny, replicate
        return P()
    if last == "conv_w":  # (k, Cd)
        return P(None, "model")
    if last == "conv_b":
        return P("model")

    # --- MoE experts (E, D, F) / (E, F, D)
    if "moe" in path and last in ("w1", "w2", "w3") and ndim == 3:
        E = shape[0]
        if E % tp == 0:  # expert parallelism
            return P("model", "data", None)
        # TP inside experts: shard the FFN dim
        return (P(None, "data", "model") if last in ("w1", "w3")
                else P(None, "model", "data"))

    # --- dense projections (2-D): column-parallel up, row-parallel down
    if last in ("w1", "w3", "wq", "wk", "wv", "xwq", "xwk", "xwv", "wq_b",
                "wk_b", "wv_b", "in_proj"):
        return P("data", "model")
    if last in ("w2", "wo", "xwo", "out_proj"):
        return P("model", "data")
    if last in ("wq_a", "wkv_a"):  # (D, small-rank)
        return P("data", None)

    # --- CNN (paper model, never sharded in production runs)
    if last in ("w", "b"):
        return P()
    raise ValueError(f"no sharding rule for {path} (ndim={ndim})")


def sanitize(mesh, spec: P, shape) -> P:
    """Drop sharding on any dim the mesh axes don't divide (pjit in/out
    shardings require exact divisibility, unlike internal constraints)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    spec_t = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    for dim, ax in zip(shape, spec_t):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        prod = 1
        for a in axes:
            prod *= sizes[a]
        out.append(ax if dim % prod == 0 else None)
    return P(*out)


DECODE_TP_BUDGET = 10 * 2**30  # per-chip weight budget for TP-only decode


def param_pspecs(cfg, mesh, params_tree, decode_tp: bool = False):
    """PartitionSpec pytree for a param tree.  Leaves under ``blocks``
    (and zamba2's (G, A, ...) stacking) get leading None axes for the
    scan-stacked layer dims.

    ``decode_tp``: drop the FSDP ("data") axis from weight shardings —
    decode is weight-memory-bound with no batch amortisation, so per-layer
    FSDP gathers cost ~15x more than reading TP-resident weights from HBM
    (EXPERIMENTS.md §Perf H2).  Use ``use_decode_tp`` to gate by budget.
    """

    def visit(path, leaf):
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        spath = "/".join(keys)
        stacked = 0
        if "blocks" in keys:
            stacked = 2 if cfg.family == "hybrid" else 1
        spec = _rule_for(cfg, mesh, spath, leaf.ndim - stacked,
                         leaf.shape[stacked:])
        if decode_tp:
            spec = P(*(None if a == "data" else a for a in tuple(spec)))
        full = P(*((None,) * stacked + tuple(spec)))
        return sanitize(mesh, full, leaf.shape)

    return jax.tree_util.tree_map_with_path(visit, params_tree)


def use_decode_tp(cfg, mesh, params_tree) -> bool:
    """TP-only decode weights iff they fit the per-chip budget."""
    import math
    tp = _axis_size(mesh, "model")
    total = sum(math.prod(l.shape) * jax.numpy.dtype(l.dtype).itemsize
                for l in jax.tree.leaves(params_tree))
    return total / tp <= DECODE_TP_BUDGET


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------

def batch_pspecs(cfg, mesh, specs: dict):
    """PartitionSpecs for an input_specs() dict (train/prefill/decode),
    sanitized against the actual shapes."""
    dp = batch_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    out = {}
    for k, v in specs.items():
        if k == "cache":
            raw = cache_pspecs(cfg, mesh)
            out[k] = jax.tree.map(
                lambda s, l: sanitize(mesh, s, l.shape), raw, v,
                is_leaf=lambda x: isinstance(x, P))
        elif k == "gout":
            out[k] = P()
        elif k in ("tokens", "labels"):
            out[k] = sanitize(mesh, P(dp, None), v.shape)
        elif k in ("embeds", "enc_out"):
            out[k] = sanitize(mesh, P(dp, None, None), v.shape)
        else:
            raise ValueError(k)
    return out


def cache_pspecs(cfg, mesh):
    dp = batch_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    out: dict = {"pos": P()}
    if cfg.family in ("dense", "vlm", "moe", "audio"):
        if cfg.attn_type == "mla":
            lay = {"ckv": P(None, dp, "model", None),
                   "kr": P(None, dp, "model", None)}
        else:
            lay = {"k": P(None, dp, "model", None, None),
                   "v": P(None, dp, "model", None, None)}
            if cfg.kv_quant:
                lay["k_scale"] = P(None, dp, "model", None)
                lay["v_scale"] = P(None, dp, "model", None)
        if cfg.family == "audio":
            lay["xk"] = P(None, dp, None, "model", None)
            lay["xv"] = P(None, dp, None, "model", None)
        out["layers"] = lay
    elif cfg.family == "ssm":
        out["layers"] = {"state": P(None, dp, "model", None, None),
                         "conv": P(None, dp, None, "model")}
    elif cfg.family == "hybrid":
        out["mamba"] = {"state": P(None, None, dp, "model", None, None),
                        "conv": P(None, None, dp, None, "model")}
        out["attn"] = {"k": P(None, dp, "model", None, None),
                       "v": P(None, dp, "model", None, None)}
    return out


def logical_constraints(cfg, mesh, exclude_pod: bool = False):
    """Returns a constrain(x, kind) fn used inside the model (MoE dispatch)."""
    dp = batch_axes(mesh)
    if exclude_pod:
        dp = tuple(a for a in dp if a != "pod")
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    tp = _axis_size(mesh, "model")
    ep = cfg.num_experts % tp == 0 if cfg.is_moe else False

    def constrain_moe(x, kind="dispatched"):
        if kind == "combine":  # (G, Sg, E, C): tokens stay on data
            spec = P(dp, None, None, None)
        else:  # (G, E, C, D) dispatched tokens: expert-parallel
            spec = (P(dp, "model", None, None) if ep
                    else P(dp, None, None, "model"))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return constrain_moe


def activation_constrainer(cfg, mesh, exclude_pod: bool = False):
    """constrain(x, kind) for repro.models.shardhooks — pins the batch axis
    (and head/state axes where divisible) at propagation-fragile points.

    ``exclude_pod``: for bodies shard_mapped over "pod" (pod axis is
    Manual there; constraints may only mention Auto axes)."""
    dp = batch_axes(mesh)
    if exclude_pod:
        dp = tuple(a for a in dp if a != "pod")
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    tp = _axis_size(mesh, "model")

    def head_axis(n):
        return "model" if (n and n % tp == 0) else None

    h_ax = head_axis(cfg.num_heads)
    kv_ax = head_axis(cfg.num_kv_heads)
    ssm_ax = head_axis(cfg.ssm_heads)

    specs = {
        "heads": P(dp, None, h_ax, None),
        "kv": P(dp, None, kv_ax, None),
        "logits": P(dp, None, "model"),
        "ssm_inner": P(dp, None, ssm_ax, None),
        "ssm_state": P(dp, ssm_ax, None, None),
    }

    def constrain(x, kind):
        if kind == "scores_seq":
            # (B, Hkv, G, T, S): decode attention scores, key axis sharded
            if x.ndim != 5 or x.shape[-1] % tp or x.shape[-2] != 1:
                return x  # only the cached-decode path
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(dp, None, None, None, "model")))
        if kind == "resid":
            if x.ndim != 3:
                return x
            if x.shape[1] == 1:
                # decode: replicate the (tiny) activations so the matmuls
                # contract against *in-place* 2D-sharded weights (partial
                # sums over "data") instead of FSDP-gathering every layer's
                # weights for 1 token (measured: -17.9 GB/step of gathers
                # on qwen2-vl decode_32k; EXPERIMENTS.md §Perf H2)
                spec = P(None, None, None)
            else:
                # sequence-parallel residual stream: the remat-saved
                # per-layer carries shard over "model" too (norms are
                # token-local, so this costs one all-gather at each
                # attention/FFN entry but divides saved-activation memory
                # by the TP degree)
                seq_ax = "model" if x.shape[1] % tp == 0 else None
                spec = P(dp, seq_ax, None)
        else:
            spec = specs.get(kind)
            if spec is None or x.ndim != len(spec):
                return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain
