"""Batched serving driver: prefill a batch of prompts, then decode with
the KV cache (greedy), on any assigned architecture (smoke preset on CPU;
the full configs serve via the same code path on the production mesh).

The fixed-batch compile-once prefill shape here is also the template for
the federated service's inference endpoint (``launch.service.
InferenceEndpoint``): the CNN is single-shot, so its endpoint is "prefill
only" — one jitted step at a fixed batch size, requests padded to it.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --batch 4 --prompt-len 64 --gen 32

Federated classifiers serve through the same fixed-batch contract via
``--classifier`` (a model registry name) plus ``--task`` (the registry
task that fixes the input geometry):

  PYTHONPATH=src python -m repro.launch.serve --classifier transformer \
      --task cifar --batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import synthetic_tokens
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.transformer import count_params, init_params


def serve(arch: str, batch: int, prompt_len: int, gen: int,
          smoke: bool = True, log=print):
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    log(f"arch={arch} params={count_params(params)/1e6:.2f}M "
        f"batch={batch} prompt={prompt_len} gen={gen}")

    total = prompt_len + gen
    prefill = jax.jit(make_prefill_step(cfg, total))
    decode = jax.jit(make_decode_step(cfg))

    prompts = synthetic_tokens(jax.random.PRNGKey(1), batch, prompt_len,
                               cfg.vocab_size)
    extra = {}
    if cfg.embed_input:
        raise SystemExit(f"{arch}: serve demo uses token archs; "
                         "vlm/audio serve via the same decode_step with "
                         "stub embeddings (see dryrun decode shapes)")
    if cfg.cross_attention:
        extra["enc_out"] = jax.random.normal(
            jax.random.PRNGKey(2),
            (batch, cfg.encoder_seq, cfg.d_model)).astype(cfg.param_dtype)

    t0 = time.time()
    logits_last, cache = prefill(params, {"tokens": prompts, **extra})
    jax.block_until_ready(logits_last)
    t_prefill = time.time() - t0
    nxt = jnp.argmax(logits_last, axis=-1).astype(jnp.int32)

    outs = [nxt]
    t0 = time.time()
    for _ in range(gen - 1):
        nxt, cache = decode(params, {"tokens": nxt[:, None],
                                     "cache": cache, **extra})
        outs.append(nxt)
    jax.block_until_ready(nxt)
    t_decode = time.time() - t0
    gen_tokens = jnp.stack(outs, axis=1)
    log(f"prefill: {t_prefill*1e3:.1f} ms "
        f"({batch * prompt_len / max(t_prefill, 1e-9):.0f} tok/s)")
    log(f"decode : {t_decode*1e3:.1f} ms "
        f"({batch * (gen - 1) / max(t_decode, 1e-9):.1f} tok/s)")
    log(f"sample continuation (seq 0): {gen_tokens[0, :12].tolist()}")
    return gen_tokens


def serve_classifier(model_name: str, task_name: str, batch: int,
                     requests: int = 40, log=print):
    """Single-shot classifier serving: build the registry model at the
    task's geometry and drive the federated inference endpoint (the
    prefill-only analogue of the decode loop above — one compiled shape,
    requests padded to it)."""
    from repro.data.pipeline import parse_task
    from repro.launch.service import InferenceEndpoint
    from repro.models.registry import build_model

    task = parse_task(task_name)
    model = build_model(model_name, task.input_shape, task.num_classes)
    params = model.init(jax.random.PRNGKey(0))
    n_par = sum(p.size for p in jax.tree.leaves(params))
    log(f"classifier={model_name} task={task.name} "
        f"input={task.input_shape} classes={task.num_classes} "
        f"params={n_par/1e3:.1f}K batch={batch}")

    endpoint = InferenceEndpoint(model.apply, batch,
                                 input_shape=task.input_shape)
    x, _ = task.data(jax.random.PRNGKey(1), requests)
    endpoint.submit(x)
    t0 = time.time()
    preds = endpoint.flush(params)
    t_serve = time.time() - t0
    log(f"served {preds.shape[0]} requests in {endpoint.batches} "
        f"batches: {t_serve*1e3:.1f} ms "
        f"({preds.shape[0] / max(t_serve, 1e-9):.0f} req/s)")
    log(f"sample predictions: {preds[:12].tolist()}")
    return preds


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--classifier", default=None,
                    help="serve a federated classifier from the model "
                         "registry instead of a token arch")
    ap.add_argument("--task", default="digits",
                    help="registry task fixing the classifier's input "
                         "geometry (with --classifier)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) config")
    args = ap.parse_args()
    if args.classifier is not None:
        serve_classifier(args.classifier, args.task, args.batch)
        return
    serve(args.arch, args.batch, args.prompt_len, args.gen,
          smoke=not args.full)


if __name__ == "__main__":
    main()
