"""Training entry point.

Two modes:

* ``--mode paper``: the letter's own experiment — federated CNN training
  over simulated wireless devices with any of fl/fd/fld/mixfld/mix2fld.

* ``--mode lm``: Mix2FLD at LM scale on the local mesh — pods (simulated
  as vmapped pod-param stacks on CPU; real pod axis on TPU) run local SGD
  steps with the KD-regularised loss, sync via the FD uplink + output-to-
  model conversion + FL downlink (launch.steps), training one of the
  assigned architectures (reduced preset by default).

Usage:
  PYTHONPATH=src python -m repro.launch.train --mode paper --protocol mix2fld
  PYTHONPATH=src python -m repro.launch.train --mode lm --arch qwen2-0.5b \
      --preset 25m --steps 50
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.channel import ChannelConfig
from repro.configs import get_config
from repro.core.protocols import FederatedConfig, FederatedTrainer
from repro.data import synthetic_tokens
from repro.launch.steps import (make_favg_step, make_fd_sync_step,
                                make_local_train_step)
from repro.models.cnn import CNN
from repro.models.transformer import count_params, init_params


def run_paper(args):
    from benchmarks.common import protocol_dataset
    dev = protocol_dataset(num_devices=args.devices, iid=not args.noniid)
    ch = ChannelConfig(num_devices=args.devices,
                       p_up_dbm=40.0 if args.symmetric else 23.0)
    fc = FederatedConfig(protocol=args.protocol, num_devices=args.devices,
                         local_iters=args.local_iters, local_batch=32,
                         server_iters=args.local_iters,
                         max_rounds=args.rounds)
    h = FederatedTrainer(CNN(), fc, ch).run(*dev, log=print)
    print(f"final acc={h['acc'][-1]:.3f} "
          f"converged_round={h['converged_round']} "
          f"cum_time={h['cum_time_s'][-1]:.1f}s")
    return h


def _preset(cfg, preset: str):
    if preset == "full":
        return cfg
    if preset == "100m":
        return dataclasses.replace(
            cfg, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
            head_dim=64, d_ff=2048, vocab_size=32000, param_dtype="float32",
            fd_buckets=64, max_position=4096)
    # 25m: CPU-friendly end-to-end demo
    return dataclasses.replace(
        cfg, num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=1536, vocab_size=8192, param_dtype="float32",
        fd_buckets=64, max_position=2048,
        num_experts=min(cfg.num_experts, 8) if cfg.is_moe else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
        moe_d_ff=1536 if cfg.is_moe else 0)


def run_lm(args):
    cfg = _preset(get_config(args.arch), args.preset)
    n_pods = args.pods
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    print(f"arch={args.arch} preset={args.preset} "
          f"params={count_params(params)/1e6:.1f}M pods={n_pods}")

    pod_params = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_pods,) + p.shape), params)
    # the server's own model state (Alg. 1: w_s persists across rounds);
    # kept pod-stacked-but-consistent so conversion runs pod-locally
    server_stack = pod_params
    local_step = jax.jit(make_local_train_step(cfg, n_pods))
    favg_step = jax.jit(jax.vmap(make_favg_step(cfg)))
    fd_sync = jax.jit(make_fd_sync_step(cfg, n_pods,
                                        ks_iters=args.ks_iters))

    B, S = args.batch, args.seq
    data = synthetic_tokens(jax.random.fold_in(key, 1),
                            n_pods * B * 8, S + 1, cfg.vocab_size)
    data = data.reshape(n_pods, B * 8, S + 1)
    seed_batch = {"tokens": data[0, :B, :]}  # inverse-mixed seeds stand-in
    gout = jnp.full((cfg.fd_buckets, cfg.fd_buckets), 1.0 / cfg.fd_buckets)

    t0 = time.time()
    for step in range(args.steps):
        k = jax.random.fold_in(key, 100 + step)
        idx = jax.random.randint(k, (n_pods, B), 0, data.shape[1])
        batch_tokens = jnp.take_along_axis(
            data, idx[..., None], axis=1)[..., :S]
        batch = {"tokens": batch_tokens,
                 "gout": jnp.broadcast_to(gout, (n_pods,) + gout.shape)}
        pod_params, metrics = local_step(pod_params, batch)
        if (step + 1) % args.sync_every == 0:
            # Mix2FLD sync: thin uplink (per-pod favg), pod-local server
            # conversion from the consistent w_s, replicated-compute
            # downlink (devices replace their params with G_mod)
            favg = favg_step(pod_params, {"tokens": batch_tokens})
            server_stack, gout = fd_sync(server_stack, favg, seed_batch)
            pod_params = server_stack
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(jnp.mean(metrics["loss"]))
            print(f"step {step:4d} loss={loss:.4f} "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps,
                  jax.tree.map(lambda p: p[0], pod_params))
        print(f"checkpoint -> {args.ckpt_dir}")
    return pod_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("paper", "lm"), default="paper")
    # paper mode
    ap.add_argument("--protocol", default="mix2fld")
    ap.add_argument("--devices", type=int, default=10)
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--local-iters", type=int, default=150)
    ap.add_argument("--noniid", action="store_true")
    ap.add_argument("--symmetric", action="store_true")
    # lm mode
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--preset", choices=("25m", "100m", "full"),
                    default="25m")
    ap.add_argument("--pods", type=int, default=2)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--sync-every", type=int, default=10)
    ap.add_argument("--ks-iters", type=int, default=4)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    if args.mode == "paper":
        run_paper(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
