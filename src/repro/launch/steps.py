"""Step builders: local train step (eq. 3 at LM scale), prefill, decode,
and the multi-pod federated sync steps (FL baseline vs the paper's FLD).

Every step is a pure function suitable for ``jax.jit(...).lower()`` with
ShapeDtypeStruct inputs — the dry-run compiles these exact programs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.outputs import bucket_log_probs, bucketize_tokens
from ..models import kvcache
from ..models.transformer import forward, unembed_matrix


# ---------------------------------------------------------------------------
# Loss: next-token CE + Mix2FLD device-side KD regularizer (eq. 3 adapted)
# ---------------------------------------------------------------------------

LOSS_CHUNK = 512  # query positions per loss chunk
# gradient-accumulation dtype: f32 is the safe default; bf16 halves the
# backward collective bytes (EXPERIMENTS.md §Perf H1) at an SGD-acceptable
# precision cost for small accum counts
_ACCUM_DTYPE = [jnp.float32]


def set_accum_dtype(dt):
    _ACCUM_DTYPE[0] = dt


def _chunk_losses(cfg, W, gout, h, tgt, msk):
    """CE + KD partial sums for one chunk. h: (B,C,D); tgt/msk: (B,C)."""
    lg = (h @ W).astype(jnp.float32)                     # (B,C,V)
    logz = jax.nn.logsumexp(lg, axis=-1)
    tl = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
    ce = jnp.sum((logz - tl) * msk)
    kd = jnp.zeros((), jnp.float32)
    if gout is not None:
        nb = cfg.fd_buckets
        blp = bucket_log_probs(lg, nb)
        tb = bucketize_tokens(tgt, cfg.vocab_size, nb)
        kd = -jnp.sum(jnp.sum(gout[tb] * blp, axis=-1) * msk)
    return ce, kd


def _lm_loss(cfg, params, batch):
    """Next-token CE + Mix2FLD KD, chunked over the sequence so the full
    (B, S, V) logits tensor never exists (measured: -9 GiB/device on
    deepseek train_4k; EXPERIMENTS.md §Perf)."""
    hidden, aux, _ = forward(cfg, params, batch, return_hidden=True)
    B, S, D = hidden.shape
    W = unembed_matrix(cfg, params)
    labels = batch["labels"] if "labels" in batch else batch["tokens"]
    # shift: position t predicts labels[t+1]; last position is masked
    targets = jnp.concatenate(
        [labels[:, 1:], jnp.zeros((B, 1), labels.dtype)], axis=1)
    mask = jnp.broadcast_to(jnp.arange(S) < S - 1, (B, S)) \
        .astype(jnp.float32)
    gout = batch.get("gout")

    C = min(LOSS_CHUNK, S)
    if S % C == 0 and S > C:
        nc = S // C
        hs = hidden.reshape(B, nc, C, D).transpose(1, 0, 2, 3)
        ts = targets.reshape(B, nc, C).transpose(1, 0, 2)
        ms = mask.reshape(B, nc, C).transpose(1, 0, 2)

        @jax.checkpoint
        def body(acc, inp):
            h, t, m = inp
            ce, kd = _chunk_losses(cfg, W, gout, h, t, m)
            return (acc[0] + ce, acc[1] + kd), None

        (ce_sum, kd_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hs, ts, ms))
    else:
        ce_sum, kd_sum = _chunk_losses(cfg, W, gout, hidden, targets, mask)

    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = ce_sum / denom
    kd = kd_sum / denom
    loss = ce + cfg.kd_beta * kd + aux
    return loss, {"ce": ce, "kd": kd, "aux": aux}


def make_train_step(cfg, grad_accum: int | None = None):
    """Paper-faithful device-side update: plain SGD on eq. (3).

    ``grad_accum`` > 1 scans over microbatches (splitting the batch dim)
    and accumulates gradients — identical SGD math, activation memory
    divided by the accumulation factor (cfg.grad_accum by default).
    """
    accum = grad_accum if grad_accum is not None else cfg.grad_accum
    loss_fn = functools.partial(_lm_loss, cfg)

    def one_grad(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(params, batch):
        per_seq = {k for k, v in batch.items()
                   if hasattr(v, "ndim") and v.ndim >= 2 and k != "gout"}
        bsz = next(v.shape[0] for k, v in batch.items() if k in per_seq)
        accum_eff = accum if (accum <= bsz and bsz % accum == 0) else 1
        if accum_eff > 1:
            micro = {k: (v.reshape((accum_eff, v.shape[0] // accum_eff)
                                   + v.shape[1:]) if k in per_seq else v)
                     for k, v in batch.items()}

            def body(acc, mb):
                b = {k: (mb[k] if k in per_seq else batch[k])
                     for k in batch}
                (loss, parts), g = one_grad(params, b)
                g_acc = jax.tree.map(jnp.add, acc[0], g)
                return (g_acc, acc[1] + loss), parts

            zeros = jax.tree.map(
                lambda p: jnp.zeros(
                    p.shape, jnp.promote_types(p.dtype, _ACCUM_DTYPE[0])),
                params)
            (grads, loss_sum), parts = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)),
                {k: v for k, v in micro.items() if k in per_seq})
            grads = jax.tree.map(lambda g: g / accum_eff, grads)
            loss = loss_sum / accum_eff
            parts = jax.tree.map(lambda x: jnp.mean(x), parts)
        else:
            (loss, parts), grads = one_grad(params, batch)
        new_params = jax.tree.map(
            lambda p, g: p - cfg.learning_rate * g.astype(p.dtype),
            params, grads)
        metrics = dict(parts, loss=loss)
        return new_params, metrics

    return train_step


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg, seq_len: int):
    """tokens/embeds (B, S) -> (last-token logits, filled cache)."""

    def prefill_step(params, batch):
        B = (batch["tokens"] if "tokens" in batch else batch["embeds"]).shape[0]
        cache = kvcache.init_cache(cfg, B, seq_len)
        logits, _, new_cache = forward(cfg, params, batch, cache=cache)
        return logits[:, -1], new_cache

    return prefill_step


def make_decode_step(cfg):
    """One token with a KV cache: greedy-sample and append."""

    def decode_step(params, batch):
        inp = {k: v for k, v in batch.items() if k != "cache"}
        logits, _, new_cache = forward(cfg, params, inp, cache=batch["cache"])
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, new_cache

    return decode_step


# ---------------------------------------------------------------------------
# Multi-pod federated sync steps (the paper's protocol at pod granularity)
# ---------------------------------------------------------------------------

def make_local_train_step(cfg, n_pods: int):
    """Pod-local Mix2FLD device update: params carry a leading pod axis and
    are updated with *no cross-pod gradient reduction* (vmap over pods);
    batch (n_pods, B/n_pods, S) shards over ("pod", "data")."""
    step = make_train_step(cfg)

    def local_step(pod_params, pod_batch):
        return jax.vmap(step)(pod_params, pod_batch)

    return local_step


def make_fl_sync_step(cfg, n_pods: int):
    """FL baseline sync: full-parameter cross-pod average (the fat uplink
    the paper avoids). pod_params leaves: (n_pods, ...) sharded on "pod"."""

    def fl_sync(pod_params):
        avg = jax.tree.map(lambda p: jnp.mean(p.astype(jnp.float32), axis=0)
                           .astype(p.dtype), pod_params)
        return jax.tree.map(
            lambda a, p: jnp.broadcast_to(a, p.shape).astype(p.dtype),
            avg, pod_params)

    return fl_sync


def make_fd_sync_step(cfg, n_pods: int, ks_iters: int = 4):
    """Mix2FLD sync (uplink FD + downlink FL):

    1. uplink: per-pod label-averaged output tables (n_pods, NB, NB) —
       O(NB^2) bytes across the pod axis instead of O(N_mod);
    2. server output-to-model conversion: ``ks_iters`` SGD+KD steps
       (eq. 5) on (inversely mixed-up) seed sequences — **vmapped over the
       pod axis**: every pod executes the identical server step on its own
       (consistent) replica, so conversion collectives are pod-local by
       construction and the only cross-pod traffic is the tiny uplink
       reduce.  This replicated-compute downlink costs zero bytes — the
       degenerate-optimal case of the paper's fat FL downlink (devices
       that cannot recompute would receive the params via the pod
       broadcast instead; both are first-class here, cf. fl_sync).
    Returns (new_pod_params, gout).
    """

    def convert(params, gout, seed_batch):
        def body(p_, _):
            def loss_fn(p):
                b = dict(seed_batch, gout=gout)
                loss, _parts = _lm_loss(cfg, p, b)
                return loss
            g = jax.grad(loss_fn)(p_)
            return jax.tree.map(
                lambda p, gg: p - cfg.learning_rate * gg.astype(p.dtype),
                p_, g), None

        converted, _ = jax.lax.scan(body, params, None, length=ks_iters)
        return converted

    def fd_sync_vmap(pod_params, pod_favg, seed_batch):
        # (1) the ONLY cross-pod collective: O(NB^2) mean over pods
        gout = jnp.mean(pod_favg, axis=0)  # (NB, NB)
        # (2)+(3) pod-local conversion of each pod's replica
        new_pod_params = jax.vmap(convert, in_axes=(0, None, None))(
            pod_params, gout, seed_batch)
        return new_pod_params, gout

    return fd_sync_vmap


def make_fd_sync_step_shardmap(cfg, mesh, ks_iters: int = 4):
    """Production multi-pod variant of :func:`make_fd_sync_step`:
    ``shard_map`` over the "pod" axis makes pod-locality *structural* —
    inside the mapped body no collective can span pods except the explicit
    ``pmean`` of the (NB, NB) uplink tables.  GSPMD still auto-shards the
    conversion over the intra-pod (data, model) axes."""
    inner = make_fd_sync_step(cfg, n_pods=1, ks_iters=ks_iters)

    def per_pod(pod_params, favg, seed_batch):
        # pod_params/favg: this pod's slice (leading dim 1)
        gout = jax.lax.pmean(favg[0], axis_name="pod")  # THE uplink
        new_pp, _ = inner(pod_params, gout[None], seed_batch)
        return new_pp, gout[None]

    from jax.sharding import PartitionSpec as P

    def fd_sync(pod_params, pod_favg, seed_batch):
        return jax.shard_map(
            per_pod,
            mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P("pod"), pod_params),
                      P("pod"),
                      jax.tree.map(lambda _: P(), seed_batch)),
            out_specs=(jax.tree.map(lambda _: P("pod"), pod_params),
                       P("pod")),
            axis_names={"pod"},
            check_vma=False,
        )(pod_params, pod_favg, seed_batch)

    return fd_sync


# ---------------------------------------------------------------------------
# Device-side FD statistics (eq. 2 at LM scale): per-bucket average output
# ---------------------------------------------------------------------------

def make_favg_step(cfg):
    """Computes the per-ground-truth-bucket average bucket-distribution
    table (NB, NB) from one batch — the Mix2FLD uplink payload."""

    def favg(params, batch):
        logits, _, _ = forward(cfg, params, batch)
        lg = logits[:, :-1].astype(jnp.float32)
        targets = (batch["labels"] if "labels" in batch
                   else batch["tokens"])[:, 1:]
        nb = cfg.fd_buckets
        bp = jnp.exp(bucket_log_probs(lg, nb))       # (B,S-1,NB)
        tb = bucketize_tokens(targets, cfg.vocab_size, nb)
        oh = jax.nn.one_hot(tb, nb, dtype=jnp.float32)
        sums = jnp.einsum("bsn,bsm->nm", oh, bp)
        cnt = jnp.sum(oh, axis=(0, 1))
        return sums / jnp.maximum(cnt[:, None], 1.0)

    return favg
