"""Distributed runtime: production meshes, sharding policy, step builders,
multi-pod dry-run driver."""
