"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is the Mix2FLD *device* axis: cross-pod DCN is the scarce
uplink, intra-pod ICI the fat downlink (DESIGN.md §3).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12   # per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke tests (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_device_mesh(num_devices: int, shards: int | None = None):
    """1-D ("data",) mesh for the federated *device* axis of the round loop
    (core.protocols, ``FederatedConfig.shard_devices``).

    shard_map blocks must be equal-sized, so the shard count defaults to
    the largest divisor of the device population that fits the local chip
    count — a 1-chip host gets a 1-shard mesh (the sharded path then
    reduces to the vmapped path exactly, which the protocol-regression
    equivalence test locks down).
    """
    avail = len(jax.devices())
    if shards is None:
        shards = max(n for n in range(1, min(num_devices, avail) + 1)
                     if num_devices % n == 0)
    if num_devices % shards:
        raise ValueError(f"device population {num_devices} not divisible "
                         f"by {shards} mesh shards")
    return jax.make_mesh((shards,), ("data",))


def data_axes(mesh) -> tuple:
    """Axes that shard the batch: ("pod","data") when pods exist."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
