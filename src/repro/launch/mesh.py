"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is the Mix2FLD *device* axis: cross-pod DCN is the scarce
uplink, intra-pod ICI the fat downlink (DESIGN.md §3).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12   # per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke tests (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_device_mesh(num_devices: int, shards: int | None = None):
    """1-D ("data",) mesh for the federated *device* axis of the round loop
    (core.protocols, ``FederatedConfig.shard_devices``).

    shard_map blocks must be equal-sized, so the shard count defaults to
    the largest divisor of the device population that fits the local chip
    count — a 1-chip host gets a 1-shard mesh (the sharded path then
    reduces to the vmapped path exactly, which the protocol-regression
    equivalence test locks down).
    """
    avail = len(jax.devices())
    if shards is None:
        shards = max(n for n in range(1, min(num_devices, avail) + 1)
                     if num_devices % n == 0)
    if num_devices % shards:
        raise ValueError(f"device population {num_devices} not divisible "
                         f"by {shards} mesh shards")
    return jax.make_mesh((shards,), ("data",))


def _largest_divisor(n: int, limit: int) -> int:
    """Largest divisor of ``n`` that is <= ``limit`` (>= 1)."""
    return max(d for d in range(1, max(1, min(n, limit)) + 1)
               if n % d == 0)


def grid_mesh_shape(grid_size: int, num_devices: int,
                    shape: tuple | None = None,
                    avail: int | None = None) -> tuple[int, int]:
    """Resolve the ``(grid_shards, device_shards)`` shape of a 2-D pod
    mesh without building it (the sweep engine re-resolves per program
    group — each group's grid slice has its own G).

    Auto-shaping greedily spends chips on the *grid* axis first: grid
    points are embarrassingly parallel (no cross-point collectives at
    all), whereas device-axis shards pay a psum per aggregation — the
    roofline model (``roofline.analysis.recommend_execution``) reaches
    the same ordering from the bytes-per-FLOP side.  Both entries must
    divide their axis (shard_map blocks are equal-sized); an explicit
    ``shape`` that doesn't is an error, the auto path picks the largest
    divisors that fit ``avail`` chips.
    """
    avail = len(jax.devices()) if avail is None else avail
    if shape is not None:
        gs, ds = int(shape[0]), int(shape[1])
        if gs < 1 or ds < 1:
            raise ValueError(f"mesh shape entries must be >= 1, "
                             f"got {shape}")
        if grid_size % gs:
            raise ValueError(f"grid size {grid_size} not divisible by "
                             f"{gs} grid shards")
        if num_devices % ds:
            raise ValueError(f"device population {num_devices} not "
                             f"divisible by {ds} device shards")
        if gs * ds > avail:
            raise ValueError(f"mesh shape {gs}x{ds} needs {gs * ds} "
                             f"chips but only {avail} are available")
        return gs, ds
    gs = _largest_divisor(grid_size, avail)
    ds = _largest_divisor(num_devices, avail // gs)
    return gs, ds


def make_grid_mesh(grid_size: int, num_devices: int,
                   shape: tuple | None = None):
    """2-D ("grid", "data") mesh for pod-scale sweeps: hyperparameter
    grid points shard along "grid", each point's federated device axis
    along "data" (``launch.sharding.federated_grid_pspecs``).  On a
    1-chip host this degenerates to a (1, 1) mesh and the shard_mapped
    program reduces to the vmapped one exactly — the same fallback
    contract as :func:`make_device_mesh`.
    """
    gs, ds = grid_mesh_shape(grid_size, num_devices, shape)
    return jax.make_mesh((gs, ds), ("grid", "data"))


def data_axes(mesh) -> tuple:
    """Axes that shard the batch: ("pod","data") when pods exist."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
