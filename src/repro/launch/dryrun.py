import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, prove it fits, and extract roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --arch ... --sync-steps

Results are written incrementally to benchmarks/results/dryrun/*.json.
"""
import argparse
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, input_specs, list_archs
from repro.launch import sharding as shp
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.steps import (make_decode_step, make_fd_sync_step,
                                make_fl_sync_step, make_prefill_step,
                                make_train_step)
from repro.models.shardhooks import set_activation_sharding
from repro.models.transformer import init_params, set_moe_constraint
from repro.roofline.analysis import (analytic_flops,
                                     collective_bytes_from_hlo,
                                     dominant_term, roofline_terms)

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")


def param_specs(cfg):
    """Parameter ShapeDtypeStructs without allocating anything."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def count_from_specs(tree) -> int:
    return sum(math.prod(l.shape) for l in jax.tree.leaves(tree))


def active_count_from_specs(cfg, tree) -> int:
    total = count_from_specs(tree)
    if not cfg.is_moe:
        return total
    moe = tree["blocks"]["moe"]
    routed = sum(math.prod(moe[w].shape) for w in ("w1", "w2", "w3"))
    return int(total - routed + routed * cfg.top_k / cfg.num_experts)


def model_flops(cfg, p_tree, shape_name: str) -> float:
    """6*N_active*D for training, 2*N_active*D for inference tokens."""
    n = active_count_from_specs(cfg, p_tree)
    s = INPUT_SHAPES[shape_name]
    tokens = s.global_batch * (s.seq_len if s.kind != "decode" else 1)
    mult = 6 if s.kind == "train" else 2
    return float(mult) * n * tokens


def _shardings(mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build(cfg, shape_name: str, mesh):
    """Returns (step_fn, arg_specs tuple, in_shardings tuple, info)."""
    specs = input_specs(cfg, shape_name)
    p_specs = param_specs(cfg)
    kind = INPUT_SHAPES[shape_name].kind
    decode_tp = kind == "decode" and shp.use_decode_tp(cfg, mesh, p_specs)
    p_shard = _shardings(mesh, shp.param_pspecs(cfg, mesh, p_specs,
                                                decode_tp=decode_tp))
    b_shard = _shardings(mesh, shp.batch_pspecs(cfg, mesh, specs))
    set_moe_constraint(shp.logical_constraints(cfg, mesh))
    set_activation_sharding(shp.activation_constrainer(cfg, mesh))
    if kind == "train":
        fn = make_train_step(cfg)
    elif kind == "prefill":
        fn = make_prefill_step(cfg, INPUT_SHAPES[shape_name].seq_len)
    else:
        fn = make_decode_step(cfg)
    return fn, (p_specs, specs), (p_shard, b_shard), {"decode_tp": decode_tp}


def dry_run_combo(arch: str, shape_name: str, multi_pod: bool,
                  save: bool = True, verbose: bool = True,
                  donate: bool = False) -> dict:
    cfg = get_config(arch)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if donate:
        mesh_name += "+donate"  # perf variant, kept apart from baselines
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not cfg.supports_shape(shape_name):
        record["status"] = "skipped"
        record["reason"] = ("long_500k needs sub-quadratic attention; "
                            f"{arch} is dense full-attention (DESIGN.md §4)")
        _save(record, save)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.devices.shape)
    t0 = time.time()
    try:
        fn, arg_specs, in_shardings, binfo = build(cfg, shape_name, mesh)
        kind = INPUT_SHAPES[shape_name].kind
        # --donate: decode donates the cache (in-place update halves
        # cache memory); kept opt-in so baselines stay comparable
        dn = (1,) if donate and kind == "decode" else ()
        out_shardings = None
        if dn:  # donation requires matching output shardings for the cache
            dp = shp.batch_axes(mesh)
            dp = dp if len(dp) > 1 else (dp[0] if dp else None)
            tok_shard = NamedSharding(mesh, P(dp))
            out_shardings = (tok_shard, in_shardings[1]["cache"])
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_shardings,
                              out_shardings=out_shardings,
                              donate_argnums=dn).lower(*arg_specs)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        # loop-aware; cross-pod classification only meaningful multi-pod
        coll = collective_bytes_from_hlo(
            hlo, pod_size=256 if multi_pod else 0)
        flops_hlo = float(cost.get("flops", 0.0))
        bytes_hlo = float(cost.get("bytes accessed", 0.0))
        arg_b = int(mem.argument_size_in_bytes)
        out_b = int(mem.output_size_in_bytes)
        tmp_b = int(mem.temp_size_in_bytes)

        # analytic FLOPs (cost_analysis counts scan bodies once) and an
        # HBM-traffic model from the buffer assignment: args + outputs
        # read/written once, temporaries written + read back.
        # CPU lowering converts every bf16 dot operand (weights, caches) to
        # f32, materialising 2x-bf16-bytes buffers that do NOT exist on
        # TPU (the MXU consumes bf16 natively).  Estimate that artifact
        # from the per-device bf16 argument bytes so records carry a
        # native-TPU peak estimate alongside the measured CPU peak.
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        bf16_args_per_chip = 0
        for l, sh in zip(jax.tree.leaves(arg_specs),
                         jax.tree.leaves(in_shardings)):
            if jnp.dtype(l.dtype) != jnp.bfloat16:
                continue
            div = 1
            for ax in jax.tree.leaves(tuple(sh.spec)):
                div *= axis_sizes.get(ax, 1)
            bf16_args_per_chip += math.prod(l.shape) * 2 // max(div, 1)
        cpu_artifact = 2 * bf16_args_per_chip
        n_active = active_count_from_specs(cfg, arg_specs[0])
        af = analytic_flops(get_config(arch), INPUT_SHAPES[shape_name],
                            n_active)
        traffic = arg_b + out_b + 2 * tmp_b
        terms = roofline_terms(af / chips, traffic, coll["total"], chips,
                               PEAK_FLOPS_BF16, HBM_BW, ICI_BW)
        mf = model_flops(cfg, arg_specs[0], shape_name)
        record.update({
            "status": "ok",
            "chips": chips,
            "compile_s": round(time.time() - t0, 1),
            "hlo_flops_per_device_loop_once": flops_hlo,
            "hlo_bytes_per_device_loop_once": bytes_hlo,
            "analytic_flops_total": af,
            "hbm_traffic_model_bytes": traffic,
            "collective_bytes_per_device": coll["total"],
            "cross_pod_bytes_per_device": coll["cross_pod"],
            "collective_breakdown": {k: v for k, v in coll.items()
                                     if k not in ("total", "counts")},
            "collective_counts": coll["counts"],
            "memory": {
                "argument_bytes": arg_b,
                "output_bytes": out_b,
                "temp_bytes": tmp_b,
                "peak_bytes": arg_b + tmp_b,
                "cpu_f32_artifact_bytes": cpu_artifact,
                "native_peak_estimate": max(arg_b + tmp_b - cpu_artifact,
                                            arg_b),
            },
            "decode_tp": binfo["decode_tp"],
            "roofline": terms,
            "dominant": dominant_term(terms),
            "model_flops_total": mf,
            # fraction of compiled compute that is "useful" model math
            "model_flops_ratio": mf / af if af else None,
        })
        if verbose:
            print(f"[ok] {arch} {shape_name} {mesh_name}: "
                  f"compile={record['compile_s']}s "
                  f"mem/device={record['memory']['peak_bytes']/2**30:.2f}GiB "
                  f"dom={record['dominant']}")
    except Exception as e:  # noqa: BLE001 — record the failure mode
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[ERROR] {arch} {shape_name} {mesh_name}: {record['error']}")
    _save(record, save)
    return record


def dry_run_sync_steps(arch: str, save: bool = True) -> list[dict]:
    """Lower the multi-pod federated steps: FL full-param sync vs the
    paper's FD sync (tiny logit uplink + server conversion)."""
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=True)
    n_pods = 2
    chips = math.prod(mesh.devices.shape)
    p_specs = param_specs(cfg)
    pod_p_specs = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((n_pods,) + l.shape, l.dtype), p_specs)
    pod_pspec = jax.tree.map(lambda s: P(*(("pod",) + tuple(s))),
                             shp.param_pspecs(cfg, mesh, p_specs),
                             is_leaf=lambda x: isinstance(x, P))
    g_shard = _shardings(mesh, shp.param_pspecs(cfg, mesh, p_specs))
    pod_shard = _shardings(mesh, pod_pspec)
    set_moe_constraint(shp.logical_constraints(cfg, mesh))

    nb = cfg.fd_buckets
    favg_spec = jax.ShapeDtypeStruct((n_pods, nb, nb), jnp.float32)
    favg_shard = NamedSharding(mesh, P("pod", None, None))
    seed_b, seed_s = 32, 512
    if cfg.embed_input:
        seed_batch = {"embeds": jax.ShapeDtypeStruct(
            (seed_b, seed_s, cfg.d_model), jnp.dtype(cfg.param_dtype)),
            "labels": jax.ShapeDtypeStruct((seed_b, seed_s), jnp.int32)}
    else:
        seed_batch = {"tokens": jax.ShapeDtypeStruct((seed_b, seed_s),
                                                     jnp.int32)}
    if cfg.cross_attention:
        seed_batch["enc_out"] = jax.ShapeDtypeStruct(
            (seed_b, cfg.encoder_seq, cfg.d_model),
            jnp.dtype(cfg.param_dtype))
    set_activation_sharding(shp.activation_constrainer(cfg, mesh))
    seed_shard = _shardings(mesh, {
        k: (P(("pod", "data"), None) if k in ("tokens", "labels")
            else P(("pod", "data"), None, None))
        for k in seed_batch})

    records = []
    for name, fn, args, in_sh in (
        ("fl_sync", make_fl_sync_step(cfg, n_pods), (pod_p_specs,),
         (pod_shard,)),
        ("fd_sync", make_fd_sync_step(cfg, n_pods),
         (pod_p_specs, favg_spec, seed_batch),
         (pod_shard, favg_shard, seed_shard)),
    ):
        # fd_sync's conversion is vmapped over "pod" (pod-local server
        # replicas): its activations must NOT claim the pod axis — that
        # forced cross-pod resharding against the pod-stacked params.
        # (A shard_map-over-pod variant exists — steps.make_fd_sync_step_
        # shardmap — but the partial-manual + GSPMD-auto combination hits
        # an XLA SPMD partitioner CHECK failure in this build; recorded.)
        set_activation_sharding(shp.activation_constrainer(
            cfg, mesh, exclude_pod=(name == "fd_sync")))
        set_moe_constraint(shp.logical_constraints(
            cfg, mesh, exclude_pod=(name == "fd_sync")))
        rec = {"arch": arch, "shape": name, "mesh": "2x16x16"}
        t0 = time.time()
        try:
            with mesh:
                lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
                compiled = lowered.compile()
            coll = collective_bytes_from_hlo(compiled.as_text(),
                                             pod_size=256)
            cost = compiled.cost_analysis()
            flops = float(cost.get("flops", 0.0))
            bytes_acc = float(cost.get("bytes accessed", 0.0))
            terms = roofline_terms(flops, bytes_acc, coll["total"], chips,
                                   PEAK_FLOPS_BF16, HBM_BW, ICI_BW)
            rec.update({
                "status": "ok", "chips": chips,
                "compile_s": round(time.time() - t0, 1),
                "hlo_flops_per_device": flops,
                "hlo_bytes_per_device": bytes_acc,
                "collective_bytes_per_device": coll["total"],
                "cross_pod_bytes_per_device": coll["cross_pod"],
                "collective_breakdown": {k: v for k, v in coll.items()
                                         if k not in ("total", "counts")},
                "roofline": terms, "dominant": dominant_term(terms),
            })
            print(f"[ok] {arch} {name}: "
                  f"coll/device={coll['total']/2**20:.2f}MiB "
                  f"cross-pod={coll['cross_pod']/2**20:.3f}MiB "
                  f"dom={rec['dominant']}")
        except Exception as e:  # noqa: BLE001
            rec["status"] = "error"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-2000:]
            print(f"[ERROR] {arch} {name}: {rec['error']}")
        _save(rec, save)
        records.append(rec)
    return records


def _save(record: dict, save: bool):
    if not save:
        return
    os.makedirs(RESULTS_DIR, exist_ok=True)
    fn = f"{record['arch']}_{record['shape']}_{record['mesh']}.json"
    with open(os.path.join(RESULTS_DIR, fn), "w") as f:
        json.dump(record, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all (arch x shape) for the selected mesh")
    ap.add_argument("--sync-steps", action="store_true",
                    help="lower the multi-pod FL/FD sync steps")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--donate", action="store_true",
                    help="donate decode caches (perf variant)")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs(assigned_only=True)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    if args.sync_steps:
        for a in archs:
            dry_run_sync_steps(a)
        return

    n_ok = n_err = 0
    for a in archs:
        for s in shapes:
            mesh_name = "2x16x16" if args.multi_pod else "16x16"
            out = os.path.join(RESULTS_DIR, f"{a}_{s}_{mesh_name}.json")
            if args.skip_existing and os.path.exists(out):
                with open(out) as f:
                    if json.load(f).get("status") in ("ok", "skipped"):
                        continue
            rec = dry_run_combo(a, s, args.multi_pod, donate=args.donate)
            n_ok += rec["status"] in ("ok", "skipped")
            n_err += rec["status"] == "error"
    print(f"done: {n_ok} ok/skipped, {n_err} errors")


if __name__ == "__main__":
    main()
