"""Optimizers (pure JAX, optax-free container): SGD (paper), momentum, Adam,
plus gradient clipping and LR schedules."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def sgd(lr) -> Optimizer:
    """Plain SGD (paper's eq. (1), constant eta). Zero optimizer memory."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"]
        eta = lr_fn(step)
        new = jax.tree.map(lambda p, g: p - eta * g.astype(p.dtype),
                           params, grads)
        return new, {"step": step + 1}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params):
        m = jax.tree.map(lambda m_, g: beta * m_ + g.astype(m_.dtype),
                         state["m"], grads)
        eta = lr_fn(state["step"])
        new = jax.tree.map(lambda p, m_: p - eta * m_.astype(p.dtype),
                           params, m)
        return new, {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                  params),
                "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                  params)}

    def update(grads, state, params):
        step = state["step"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ +
                         (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        eta = lr_fn(step)
        sf = step.astype(jnp.float32)
        bc1 = 1 - b1 ** sf
        bc2 = 1 - b2 ** sf

        def upd(p, m_, v_):
            mh = m_ / bc1
            vh = v_ / bc2
            return p - (eta * mh / (jnp.sqrt(vh) + eps)).astype(p.dtype)

        return (jax.tree.map(upd, params, m, v),
                {"step": step, "m": m, "v": v})

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return lr


def get_optimizer(name: str, lr) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adam": adam}[name](lr)
