"""Flash attention forward kernel (prefill hot path).

Grid (batch*heads, q_blocks, kv_blocks); the kv dim is the minor-most
grid axis, so iterations over it are sequential on TPU and the online-
softmax state (m, l, o accumulator) lives in VMEM scratch across them.
Causal masking by absolute positions; optional sliding window.

Block sizes are MXU-aligned (128 multiples) and sized so the working set
(q, k, v blocks + accumulator) stays a few MB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Q_BLOCK = 256
KV_BLOCK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, window, blk_q, blk_k, seq_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                      # (blk_q, d)
    k = k_ref[0]                      # (blk_k, d)
    v = v_ref[0]                      # (blk_k, dv)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _final():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "interpret", "blk_q", "blk_k"))
def flash_attention_pallas(q, k, v, *, window=None, interpret: bool = True,
                           blk_q: int = Q_BLOCK, blk_k: int = KV_BLOCK):
    """q, k, v: (BH, S, d) — heads pre-flattened into the batch dim,
    grouped-query repetition done by the caller.  Causal.  Returns
    (BH, S, dv)."""
    bh, s, d = q.shape
    dv = v.shape[-1]
    blk_q = min(blk_q, s)
    blk_k = min(blk_k, s)
    assert s % blk_q == 0 and s % blk_k == 0, (s, blk_q, blk_k)
    scale = 1.0 / (d ** 0.5)
    grid = (bh, s // blk_q, s // blk_k)
    kernel = functools.partial(_flash_kernel, scale=scale, window=window,
                               blk_q=blk_q, blk_k=blk_k, seq_len=s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
