"""Pallas TPU kernels for the compute hot spots, each with a pure-jnp
oracle in ref.py and a jit wrapper in ops.py.

  mixup_kernel    — two-way Mixup / inverse-Mixup batch transform (eq. 6/7)
  distill_loss    — fused softmax CE + KD regularizer (eq. 3/5)
  flash_attention — block-tiled online-softmax attention (prefill path)
  ssd_scan        — Mamba2 SSD chunked scan (state-space duality)

On CPU (this container) kernels run with interpret=True; on TPU the same
pallas_call lowers to Mosaic.
"""
