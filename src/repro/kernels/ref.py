"""Pure-jnp oracles for every kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.mamba2 import ssd_chunked


def mixup_ref(a, b, lam_a, lam_b):
    return (lam_a[:, None].astype(jnp.float32) * a +
            lam_b[:, None].astype(jnp.float32) * b).astype(a.dtype)


def distill_loss_ref(logits, labels, g_rows, beta):
    z = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(z, axis=-1)
    zy = jnp.take_along_axis(z, labels[:, None], axis=-1)[:, 0]
    gz = jnp.sum(g_rows.astype(jnp.float32) * z, axis=-1)
    return (lse - zy) + beta * (lse - gz)


def attention_ref(q, k, v, window=None):
    """Causal attention, (BH, S, d) layout."""
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) / (q.shape[-1] ** 0.5)
    S = q.shape[1]
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v).astype(q.dtype)


def ssd_ref(xdt, Bh, Ch, dA):
    """Exact sequential SSD recurrence. xdt: (BH,S,P); Bh/Ch: (BH,S,N);
    dA: (BH,S). Matches ssd_scan_pallas semantics."""
    bh, s, p = xdt.shape
    n = Bh.shape[-1]

    def per_bh(x, B, C, da):
        def step(state, inp):
            xt, bt, ct, at = inp
            state = jnp.exp(at) * state + jnp.outer(bt, xt)  # (N, P)
            return state, ct @ state

        _, ys = jax.lax.scan(step, jnp.zeros((n, p), jnp.float32),
                             (x.astype(jnp.float32), B.astype(jnp.float32),
                              C.astype(jnp.float32), da.astype(jnp.float32)))
        return ys

    return jax.vmap(per_bh)(xdt, Bh, Ch, dA).astype(xdt.dtype)


# re-export: the model's chunked SSD is itself validated against ssd_ref
ssd_chunked_ref = ssd_chunked
