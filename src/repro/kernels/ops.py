"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only); on
TPU backends the same pallas_call lowers to Mosaic.
"""
from __future__ import annotations

import jax.numpy as jnp

from .distill_loss import distill_loss_pallas
from .flash_attention import flash_attention_pallas
from .mixup_kernel import mixup_pallas
from .runtime import default_interpret as _interpret
from .ssd_scan import ssd_scan_pallas


def mixup(a, b, lam: float):
    """eq. (6): lam * a + (1 - lam) * b over a batch of flattened samples."""
    n = a.shape[0]
    flat_a = a.reshape(n, -1)
    flat_b = b.reshape(n, -1)
    la = jnp.full((n,), lam, jnp.float32)
    lb = jnp.full((n,), 1.0 - lam, jnp.float32)
    out = mixup_pallas(flat_a, flat_b, la, lb, interpret=_interpret())
    return out.reshape(a.shape)


def inverse_mixup_pair(mixed_a, mixed_b, lam: float):
    """eq. (7), N=2: returns the two hard-labelled unmixed samples."""
    lam_hat = lam / (2.0 * lam - 1.0)
    n = mixed_a.shape[0]
    fa = mixed_a.reshape(n, -1)
    fb = mixed_b.reshape(n, -1)
    l1 = jnp.full((n,), lam_hat, jnp.float32)
    l2 = 1.0 - l1
    s1 = mixup_pallas(fa, fb, l1, l2, interpret=_interpret())
    s2 = mixup_pallas(fa, fb, l2, l1, interpret=_interpret())
    return s1.reshape(mixed_a.shape), s2.reshape(mixed_a.shape)


def distill_loss(logits, labels, gout, beta: float):
    """Mean of eq. (3) over a batch; gout: (C, C) KD table."""
    g_rows = gout[labels]
    per = distill_loss_pallas(logits, labels, g_rows, beta,
                              interpret=_interpret())
    return jnp.mean(per)


def flash_attention(q, k, v, *, window=None):
    """Causal attention, (BH, S, d) layout (see kernels/flash_attention)."""
    return flash_attention_pallas(q, k, v, window=window,
                                  interpret=_interpret())


def ssd_scan(xdt, Bh, Ch, dA, *, chunk: int = 64):
    """Mamba2 SSD over (BH, S, ·) tensors."""
    return ssd_scan_pallas(xdt, Bh, Ch, dA, chunk=chunk,
                           interpret=_interpret())
