"""Shared kernel-dispatch policy."""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Compile on TPU (Mosaic), interpret everywhere else (CPU tests)."""
    return jax.default_backend() != "tpu"
