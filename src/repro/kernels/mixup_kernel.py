"""Two-way Mixup batch transform kernel (eq. 6 / 7).

out[i] = lam_a[i] * a[i] + lam_b[i] * b[i]

covers both device-side Mixup (lam, 1-lam) and server-side inverse-Mixup
(lam_hat, 1-lam_hat, which are extrapolating ratios).  Tiled (rows x
features) with both operands resident in VMEM; rows is the batch of
(possibly flattened) samples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import default_interpret as _default_interpret

ROW_BLOCK = 256
COL_BLOCK = 512


def _mixup_kernel(a_ref, b_ref, la_ref, lb_ref, o_ref):
    a = a_ref[...]
    b = b_ref[...]
    la = la_ref[...]  # (rows, 1)
    lb = lb_ref[...]
    o_ref[...] = (la * a + lb * b).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mixup_pallas(a, b, lam_a, lam_b, *, interpret: bool | None = None):
    """a, b: (N, F); lam_a, lam_b: (N,). Returns (N, F).

    ``interpret=None`` resolves per backend (:func:`_default_interpret`),
    so callers on the hot path (``core.protocols.collect_seeds``) get the
    compiled Mosaic kernel on TPU and the reference interpreter on CPU.
    """
    if interpret is None:
        interpret = _default_interpret()
    n, f = a.shape
    rb = min(ROW_BLOCK, n)
    cb = min(COL_BLOCK, f)
    if n % rb or f % cb:  # pad to block multiples
        np_, fp = -(-n // rb) * rb, -(-f // cb) * cb
        a = jnp.pad(a, ((0, np_ - n), (0, fp - f)))
        b = jnp.pad(b, ((0, np_ - n), (0, fp - f)))
        lam_a = jnp.pad(lam_a, (0, np_ - n))
        lam_b = jnp.pad(lam_b, (0, np_ - n))
    la = lam_a[:, None].astype(jnp.float32)
    lb = lam_b[:, None].astype(jnp.float32)
    grid = (a.shape[0] // rb, a.shape[1] // cb)
    out = pl.pallas_call(
        _mixup_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
            pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
            pl.BlockSpec((rb, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((rb, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b, la, lb)
    return out[:n, :f]
