"""Mamba2 SSD chunk-scan kernel (state-space duality).

Grid (batch*heads, chunks); the chunk axis is minor-most, so iterations
are sequential and the recurrent state (N, P) is carried in VMEM scratch:

  intra:  y_l += sum_{m<=l} exp(seg_l - seg_m) (C_l . B_m) x_m dt_m
  state:  S_c  = exp(seg_last) S_{c-1} + sum_m exp(seg_last - seg_m) B_m (x_m dt_m)^T
  inter:  y_l += exp(seg_l) C_l . S_{c-1}

Inputs are per-(b,h) chunk tiles: x (L, P), B/C (L, N), dA (L, 1).
TPU adaptation: the L x L decay/score matrix is built with MXU-friendly
dots; the state stays resident in VMEM across the whole sequence (one
HBM round-trip per chunk, vs. L for the naive recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, da_ref, y_ref, state_scr, *, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)      # (L, P)  (already x * dt)
    B = b_ref[0].astype(jnp.float32)      # (L, N)
    C = c_ref[0].astype(jnp.float32)      # (L, N)
    dA = da_ref[0].astype(jnp.float32)    # (L, 1)

    seg = jnp.cumsum(dA, axis=0)          # (L, 1) inclusive
    # ---- intra-chunk ----
    decay = seg - seg.T                   # (L, L): seg_l - seg_m
    l_idx = jax.lax.broadcasted_iota(jnp.int32, decay.shape, 0)
    m_idx = jax.lax.broadcasted_iota(jnp.int32, decay.shape, 1)
    att = jnp.where(m_idx <= l_idx, jnp.exp(decay), 0.0)
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    y = jax.lax.dot_general(cb * att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (L, P)

    # ---- inter-chunk: contribution of the incoming state ----
    prev = state_scr[...]                 # (N, P)
    y += jnp.exp(seg) * jax.lax.dot_general(
        C, prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # ---- state update ----
    seg_last = seg[chunk - 1:chunk, :]    # (1, 1)
    w = jnp.exp(seg_last - seg)           # (L, 1)
    new_state = jnp.exp(seg_last) * prev + jax.lax.dot_general(
        B * w, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (N, P)
    state_scr[...] = new_state

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(xdt, Bh, Ch, dA, *, chunk: int = 64,
                    interpret: bool = True):
    """xdt: (BH, S, P) = x * dt; Bh/Ch: (BH, S, N); dA: (BH, S) (<= 0).
    Returns y: (BH, S, P).  Per-(batch, head) layout — the caller
    flattens (B, H) and broadcasts groups."""
    bh, s, p = xdt.shape
    n = Bh.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    grid = (bh, s // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xdt, Bh, Ch, dA[..., None])
