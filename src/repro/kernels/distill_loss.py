"""Fused distillation loss kernels (eq. 3 / 5).

Per sample i with logits z_i (C classes), label y_i and KD target row
g_i (the G_out row of y_i's ground truth):

  phi_i = logsumexp(z_i) - z_i[y_i]
  psi_i = sum_c g_ic * (logsumexp(z_i) - z_ic)
  out_i = phi_i + beta * psi_i

One VMEM pass per (row-block x full class dim): max, exp-sum, label pick
and KD dot all fused — the server's output-to-model conversion (eq. 5)
runs this over every seed sample for K_s iterations.

Two entry points:

* :func:`distill_loss_pallas` — the original fused ``phi + beta * psi``
  (forward only; assumes rows of g sum to 1, as G_out rows do).
* :func:`distill_phi_psi` — per-sample (phi, psi) with a ``custom_vjp``
  whose backward pass is a second fused kernel, so the *device-side*
  local-SGD hot path (``core.losses.fd_loss`` under ``value_and_grad``
  inside the round loop's scan) runs both directions through Pallas.
  psi here carries the exact ``sum(g) * lse`` term, so it matches
  ``kd_regularizer`` even for unnormalised / zero G_out rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .runtime import default_interpret as _default_interpret

ROW_BLOCK = 128


def _distill_kernel(z_ref, y_ref, g_ref, beta_ref, o_ref):
    z = z_ref[...].astype(jnp.float32)          # (R, C)
    y = y_ref[...]                              # (R, 1) int32
    g = g_ref[...].astype(jnp.float32)          # (R, C)
    beta = beta_ref[0, 0]
    m = jnp.max(z, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - m), axis=-1, keepdims=True)) + m
    onehot = (jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) == y)
    zy = jnp.sum(jnp.where(onehot, z, 0.0), axis=-1, keepdims=True)
    gz = jnp.sum(g * z, axis=-1, keepdims=True)
    phi = lse - zy
    psi = lse - gz
    o_ref[...] = (phi + beta * psi).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def distill_loss_pallas(logits, labels, g_rows, beta, *,
                        interpret: bool = True):
    """logits: (N, C); labels: (N,) int32; g_rows: (N, C) KD target rows;
    beta: scalar. Returns per-sample losses (N,)."""
    n, c = logits.shape
    rb = min(ROW_BLOCK, n)
    if n % rb:
        pad = -(-n // rb) * rb - n
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad))
        g_rows = jnp.pad(g_rows, ((0, pad), (0, 0)))
    beta_arr = jnp.full((1, 1), beta, jnp.float32)
    out = pl.pallas_call(
        _distill_kernel,
        grid=(logits.shape[0] // rb,),
        in_specs=[
            pl.BlockSpec((rb, c), lambda i: (i, 0)),
            pl.BlockSpec((rb, 1), lambda i: (i, 0)),
            pl.BlockSpec((rb, c), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((logits.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(logits, labels[:, None].astype(jnp.int32), g_rows, beta_arr)
    return out[:n, 0]


# ---------------------------------------------------------------------------
# custom_vjp pair: per-sample (phi, psi) with a fused backward kernel
# ---------------------------------------------------------------------------

def _phi_psi_kernel(z_ref, y_ref, g_ref, phi_ref, psi_ref):
    z = z_ref[...].astype(jnp.float32)          # (R, C)
    y = y_ref[...]                              # (R, 1) int32
    g = g_ref[...].astype(jnp.float32)          # (R, C)
    m = jnp.max(z, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - m), axis=-1, keepdims=True)) + m
    onehot = (jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) == y)
    zy = jnp.sum(jnp.where(onehot, z, 0.0), axis=-1, keepdims=True)
    sg = jnp.sum(g, axis=-1, keepdims=True)     # G_out rows may be unnorm.
    gz = jnp.sum(g * z, axis=-1, keepdims=True)
    phi_ref[...] = (lse - zy).astype(phi_ref.dtype)
    psi_ref[...] = (sg * lse - gz).astype(psi_ref.dtype)


def _phi_psi_bwd_kernel(z_ref, y_ref, g_ref, dphi_ref, dpsi_ref,
                        dz_ref, dg_ref):
    z = z_ref[...].astype(jnp.float32)
    y = y_ref[...]
    g = g_ref[...].astype(jnp.float32)
    dphi = dphi_ref[...].astype(jnp.float32)    # (R, 1)
    dpsi = dpsi_ref[...].astype(jnp.float32)
    m = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - m)
    lse = jnp.log(jnp.sum(e, axis=-1, keepdims=True)) + m
    p = e / jnp.sum(e, axis=-1, keepdims=True)  # softmax rows
    onehot = (jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) == y)
    sg = jnp.sum(g, axis=-1, keepdims=True)
    # d phi / dz = p - onehot;  d psi / dz = sum(g) * p - g
    dz_ref[...] = (dphi * (p - jnp.where(onehot, 1.0, 0.0)) +
                   dpsi * (sg * p - g)).astype(dz_ref.dtype)
    # d psi / dg = lse - z (phi does not touch g)
    dg_ref[...] = (dpsi * (lse - z)).astype(dg_ref.dtype)


def _pad_rows(n, rb, *arrs):
    pad = -(-n // rb) * rb - n
    if pad == 0:
        return arrs
    return tuple(jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
                 for a in arrs)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _phi_psi_fwd_call(logits, labels, g_rows, interpret: bool):
    n, c = logits.shape
    rb = min(ROW_BLOCK, n)
    y2 = labels[:, None].astype(jnp.int32)
    logits, y2, g_rows = _pad_rows(n, rb, logits, y2, g_rows)
    spec_c = pl.BlockSpec((rb, c), lambda i: (i, 0))
    spec_1 = pl.BlockSpec((rb, 1), lambda i: (i, 0))
    phi, psi = pl.pallas_call(
        _phi_psi_kernel,
        grid=(logits.shape[0] // rb,),
        in_specs=[spec_c, spec_1, spec_c],
        out_specs=[spec_1, spec_1],
        out_shape=[jax.ShapeDtypeStruct((logits.shape[0], 1), jnp.float32)] * 2,
        interpret=interpret,
    )(logits, y2, g_rows)
    return phi[:n, 0], psi[:n, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def _phi_psi_bwd_call(logits, labels, g_rows, dphi, dpsi, interpret: bool):
    n, c = logits.shape
    rb = min(ROW_BLOCK, n)
    y2 = labels[:, None].astype(jnp.int32)
    logits, y2, g_rows, dphi2, dpsi2 = _pad_rows(
        n, rb, logits, y2, g_rows, dphi[:, None], dpsi[:, None])
    spec_c = pl.BlockSpec((rb, c), lambda i: (i, 0))
    spec_1 = pl.BlockSpec((rb, 1), lambda i: (i, 0))
    dz, dg = pl.pallas_call(
        _phi_psi_bwd_kernel,
        grid=(logits.shape[0] // rb,),
        in_specs=[spec_c, spec_1, spec_c, spec_1, spec_1],
        out_specs=[spec_c, spec_c],
        out_shape=[jax.ShapeDtypeStruct(logits.shape, jnp.float32)] * 2,
        interpret=interpret,
    )(logits, y2, g_rows, dphi2, dpsi2)
    return dz[:n], dg[:n]


@jax.custom_vjp
def distill_phi_psi(logits, labels, g_rows):
    """Per-sample (phi, psi): logits (N, C); labels (N,) int; g_rows (N, C)
    KD target rows.  Forward *and* backward run as fused Pallas kernels
    (interpret off-TPU), differentiable in logits and g_rows."""
    return _phi_psi_fwd_call(logits, labels, g_rows,
                             interpret=_default_interpret())


def _distill_phi_psi_fwd(logits, labels, g_rows):
    out = _phi_psi_fwd_call(logits, labels, g_rows,
                            interpret=_default_interpret())
    return out, (logits, labels, g_rows)


def _distill_phi_psi_bwd(res, cts):
    logits, labels, g_rows = res
    dphi, dpsi = cts
    dz, dg = _phi_psi_bwd_call(logits, labels, g_rows, dphi, dpsi,
                               interpret=_default_interpret())
    return (dz.astype(logits.dtype),
            np.zeros(labels.shape, jax.dtypes.float0),
            dg.astype(g_rows.dtype))


distill_phi_psi.defvjp(_distill_phi_psi_fwd, _distill_phi_psi_bwd)
