"""Fused distillation loss kernel (eq. 3 / 5).

Per sample i with logits z_i (C classes), label y_i and KD target row
g_i (the G_out row of y_i's ground truth):

  phi_i = logsumexp(z_i) - z_i[y_i]
  psi_i = logsumexp(z_i) - sum_c g_ic * z_ic      (sum g = 1)
  out_i = phi_i + beta * psi_i

One VMEM pass per (row-block x full class dim): max, exp-sum, label pick
and KD dot all fused — the server's output-to-model conversion (eq. 5)
runs this over every seed sample for K_s iterations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 128


def _distill_kernel(z_ref, y_ref, g_ref, beta_ref, o_ref):
    z = z_ref[...].astype(jnp.float32)          # (R, C)
    y = y_ref[...]                              # (R, 1) int32
    g = g_ref[...].astype(jnp.float32)          # (R, C)
    beta = beta_ref[0, 0]
    m = jnp.max(z, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(z - m), axis=-1, keepdims=True)) + m
    onehot = (jax.lax.broadcasted_iota(jnp.int32, z.shape, 1) == y)
    zy = jnp.sum(jnp.where(onehot, z, 0.0), axis=-1, keepdims=True)
    gz = jnp.sum(g * z, axis=-1, keepdims=True)
    phi = lse - zy
    psi = lse - gz
    o_ref[...] = (phi + beta * psi).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def distill_loss_pallas(logits, labels, g_rows, beta, *,
                        interpret: bool = True):
    """logits: (N, C); labels: (N,) int32; g_rows: (N, C) KD target rows;
    beta: scalar. Returns per-sample losses (N,)."""
    n, c = logits.shape
    rb = min(ROW_BLOCK, n)
    if n % rb:
        pad = -(-n // rb) * rb - n
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, (0, pad))
        g_rows = jnp.pad(g_rows, ((0, pad), (0, 0)))
    beta_arr = jnp.full((1, 1), beta, jnp.float32)
    out = pl.pallas_call(
        _distill_kernel,
        grid=(logits.shape[0] // rb,),
        in_specs=[
            pl.BlockSpec((rb, c), lambda i: (i, 0)),
            pl.BlockSpec((rb, 1), lambda i: (i, 0)),
            pl.BlockSpec((rb, c), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((logits.shape[0], 1), jnp.float32),
        interpret=interpret,
    )(logits, labels[:, None].astype(jnp.int32), g_rows, beta_arr)
    return out[:n, 0]
