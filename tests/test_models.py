"""Per-architecture smoke tests (reduced same-family variants): one
forward + one train step on CPU, asserting shapes and finiteness; plus
full-vs-incremental decode parity for every cached family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.launch.steps import make_train_step
from repro.models.cnn import CNN
from repro.models.transformer import Transformer, count_params

ASSIGNED = [a for a in list_archs(assigned_only=True)]


def _smoke_batch(cfg, key, B=2, S=32, decode=False):
    T = 1 if decode else S
    batch = {}
    if cfg.embed_input:
        batch["embeds"] = jax.random.normal(key, (B, T, cfg.d_model)) * 0.1
        if not decode:
            batch["labels"] = jax.random.randint(
                jax.random.fold_in(key, 9), (B, T), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    if cfg.cross_attention:
        batch["enc_out"] = jax.random.normal(
            jax.random.fold_in(key, 1), (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_config(arch).smoke()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    m = Transformer(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    logits, aux, _ = m.apply(params, _smoke_batch(cfg, jax.random.PRNGKey(1)))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step_reduces_loss(arch):
    cfg = dataclasses.replace(get_config(arch).smoke(), learning_rate=0.05)
    m = Transformer(cfg)
    params = m.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1), B=4, S=32)
    batch["gout"] = jnp.full((cfg.fd_buckets, cfg.fd_buckets),
                             1.0 / cfg.fd_buckets)
    params, m0 = step(params, batch)
    for _ in range(8):
        params, mN = step(params, batch)
    assert bool(jnp.isfinite(mN["loss"]))
    assert float(mN["loss"]) < float(m0["loss"])  # memorise one batch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).smoke()
    m = Transformer(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1), B=B, S=S)
    full, _, _ = m.apply(params, batch)
    cache = m.init_cache(B, S)
    outs = []
    for t in range(S):
        db = {}
        if cfg.embed_input:
            db["embeds"] = batch["embeds"][:, t:t + 1]
        else:
            db["tokens"] = batch["tokens"][:, t:t + 1]
        if cfg.cross_attention:
            db["enc_out"] = batch["enc_out"]
        lg, _, cache = m.apply(params, db, cache=cache)
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(full - inc))) / \
        max(float(jnp.max(jnp.abs(full))), 1e-9)
    assert rel < 5e-3, f"{arch}: decode parity rel err {rel}"


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "mamba2-370m",
                                  "zamba2-2.7b", "deepseek-v2-236b"])
def test_prefill_then_decode_continues_correctly(arch):
    cfg = get_config(arch).smoke()
    m = Transformer(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 1, 160  # > smoke sliding window (128): exercises ring caches
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1), B=B, S=S + 1)
    full, _, _ = m.apply(params, batch)
    cache = m.init_cache(B, S + 1)
    pre = {k: (v[:, :S] if k in ("tokens", "embeds") else v)
           for k, v in batch.items()}
    last = {k: (v[:, S:S + 1] if k in ("tokens", "embeds") else v)
            for k, v in batch.items()}
    lg_pre, _, cache = m.apply(params, pre, cache=cache)
    lg_dec, _, _ = m.apply(params, last, cache=cache)
    scale = float(jnp.max(jnp.abs(full)))
    assert float(jnp.max(jnp.abs(lg_pre - full[:, :S]))) / scale < 5e-3
    assert float(jnp.max(jnp.abs(lg_dec[:, 0] - full[:, S]))) / scale < 5e-3


def test_cnn_param_count_close_to_paper():
    model = CNN()
    params = model.init(jax.random.PRNGKey(0))
    n = model.num_params(params)
    assert abs(n - 12544) < 200, n  # paper: N_mod = 12,544 (shapes unpublished)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 28, 28, 1))
    logits = model.apply(params, x)
    assert logits.shape == (4, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_count_params_matches_leaf_sum():
    cfg = get_config("qwen2-0.5b").smoke()
    m = Transformer(cfg)
    params = m.init(jax.random.PRNGKey(0))
    assert count_params(params) == sum(x.size for x in jax.tree.leaves(params))


@pytest.mark.slow
def test_int8_kv_cache_decode_parity():
    """Beyond-paper: int8 KV cache (halves the decode memory roofline
    term) stays within quantisation tolerance of the exact forward."""
    cfg = dataclasses.replace(get_config("qwen2-0.5b").smoke(),
                              kv_quant=True)
    m = Transformer(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full, _, _ = m.apply(params, {"tokens": toks})
    cache = m.init_cache(B, S)
    assert cache["layers"]["k"].dtype == jnp.int8
    outs = []
    for t in range(S):
        lg, _, cache = m.apply(params, {"tokens": toks[:, t:t + 1]},
                               cache=cache)
        outs.append(lg[:, 0])
    inc = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(full - inc))) / \
        max(float(jnp.max(jnp.abs(full))), 1e-9)
    assert rel < 0.05, rel
