"""Link pipeline seam: codec registry, codec-aware payload accounting,
encode/decode round trips, the DP accountant, and the shared protocol
registry (tests/test_protocols.py's goldens lock the identity codec to
the pre-pipeline histories on all five protocols; tests/test_sweep.py
locks the two round-loop paths together)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import ChannelConfig
from repro.channel.payload import (B_MOD, B_OUT, CODECS, CodecSpec,
                                   parse_codec, payload_bits,
                                   round_payload_bits, round_slot_plan)
from repro.channel.pipeline import (LinkPlan, downlink_gout,
                                    downlink_params, uplink_stage)
from repro.core.privacy import (GaussianAccountant, gaussian_epsilon,
                                gaussian_mechanism)
from repro.core.protocols import FederatedConfig
from repro.registry import (FLD_FAMILY, PROTOCOLS, canonical_protocol)

# paper geometry: MNIST MLP weights, 10 classes, 8-bit 28x28 seed samples
N_MOD, N_L, B_S, N_S = 12544, 10, 6272, 10


# ---------------------------------------------------------------------------
# Satellite: one protocol registry, shared by every layer
# ---------------------------------------------------------------------------

def test_registry_aliases_resolve_everywhere():
    assert canonical_protocol("mix2fd") == "mixfld"
    for p in PROTOCOLS:
        assert canonical_protocol(p) == p
    # payload accounting accepts the alias spelling...
    assert payload_bits("mix2fd", n_mod=N_MOD, n_labels=N_L) == \
        payload_bits("mixfld", n_mod=N_MOD, n_labels=N_L)
    # ...and so does the trainer config (canonicalized on construction)
    assert FederatedConfig(protocol="mix2fd").protocol == "mixfld"


def test_registry_unknown_name_same_error_everywhere():
    for raiser in (
            lambda: canonical_protocol("mix2lfd"),
            lambda: payload_bits("mix2lfd", n_mod=1, n_labels=1),
            lambda: FederatedConfig(protocol="mix2lfd")):
        with pytest.raises(ValueError, match="unknown protocol") as e:
            raiser()
        for p in PROTOCOLS:  # the error lists the valid set
            assert p in str(e.value)


# ---------------------------------------------------------------------------
# Codec registry
# ---------------------------------------------------------------------------

def test_parse_codec_families_and_params():
    assert parse_codec("identity") == CodecSpec()
    assert parse_codec("quantize4").quant_bits == 4
    assert parse_codec("quantize4").levels == 15.0
    assert parse_codec("dp_gaussian0.5").dp_sigma == 0.5
    assert parse_codec("delta").name == "delta"
    # spec strings override the keyword defaults; bare names keep them
    assert parse_codec("quantize", quant_bits=16).quant_bits == 16
    spec = parse_codec("quantize8", quant_bits=16)
    assert spec.quant_bits == 8
    assert parse_codec(spec) is spec  # CodecSpec passes through


def test_parse_codec_rejects_bad_specs():
    with pytest.raises(ValueError, match="unknown codec"):
        parse_codec("zstd")
    with pytest.raises(ValueError, match="no numeric parameter"):
        parse_codec("identity5")
    with pytest.raises(ValueError, match="bits must be in"):
        parse_codec("quantize0")
    with pytest.raises(ValueError, match="sigma > 0"):
        parse_codec("dp_gaussian", dp_sigma=0.0)
    for fam in CODECS:  # the error lists registered families
        assert fam in str(pytest.raises(
            ValueError, parse_codec, "zstd").value)


def test_federated_config_validates_codec():
    with pytest.raises(ValueError, match="unknown codec"):
        FederatedConfig(codec="zstd")
    fc = FederatedConfig(codec="quantize4", dp_sigma=2.0)
    assert fc.codec_spec().quant_bits == 4
    assert fc.codec_spec().dp_sigma == 2.0


# ---------------------------------------------------------------------------
# Codec-aware payload accounting: bits and slots respond to compression
# ---------------------------------------------------------------------------

def test_round_payload_bits_explicit_first_steady_pair():
    pay = round_payload_bits("mix2fld", n_mod=N_MOD, n_labels=N_L,
                             sample_bits=B_S, n_seed=N_S)
    assert pay.up_first == B_OUT * N_L * N_L + B_S * N_S
    assert pay.up_steady == B_OUT * N_L * N_L
    assert pay.dn == B_MOD * N_MOD
    # the two payload_bits views agree with the pair
    up1, _ = payload_bits("mix2fld", n_mod=N_MOD, n_labels=N_L,
                          sample_bits=B_S, n_seed=N_S, first_round=True)
    up, dn = payload_bits("mix2fld", n_mod=N_MOD, n_labels=N_L,
                          sample_bits=B_S, n_seed=N_S)
    assert (up1, up, dn) == (pay.up_first, pay.up_steady, pay.dn)


def test_paper_uplink_reduction_ratio():
    """Sec. V: Mix2FLD's amortized uplink traffic over R=10 rounds is
    42.4x smaller than FL's (seed samples ride along only once)."""
    R = 10
    fl = round_payload_bits("fl", n_mod=N_MOD, n_labels=N_L)
    mx = round_payload_bits("mix2fld", n_mod=N_MOD, n_labels=N_L,
                            sample_bits=B_S, n_seed=N_S)
    ratio = (R * fl.up_steady) / (mx.up_first + (R - 1) * mx.up_steady)
    assert abs(ratio - 42.4) < 0.1


@pytest.mark.parametrize("proto", PROTOCOLS)
def test_quantize_codec_shrinks_uplink_only(proto):
    raw = round_payload_bits(proto, n_mod=N_MOD, n_labels=N_L,
                             sample_bits=B_S, n_seed=N_S)
    q = round_payload_bits(proto, n_mod=N_MOD, n_labels=N_L,
                           sample_bits=B_S, n_seed=N_S, codec="quantize8")
    assert q.up_steady == raw.up_steady // 4   # 32 -> 8 bits/element
    assert q.dn == raw.dn                      # downlink stays raw
    if proto in FLD_FAMILY:   # first-round seed samples stay raw
        assert q.up_first - q.up_steady == B_S * N_S


def test_round_slot_plan_latency_responds_to_compression():
    ch = ChannelConfig(num_devices=4, p_up_dbm=40.0)
    raw = round_slot_plan("fd", ch, n_mod=N_MOD, n_labels=N_L)
    q4 = round_slot_plan("fd", ch, n_mod=N_MOD, n_labels=N_L,
                         codec="quantize4")
    assert q4["up_bits"] == raw["up_bits"] / 8
    assert q4["up_slots"] <= raw["up_slots"]
    assert q4["dn_slots"] == raw["dn_slots"]
    # LinkPlan carries the same accounting into the round loop
    plan = LinkPlan.build("fd", ch, n_mod=N_MOD, n_labels=N_L,
                          codec="quantize4")
    assert plan.up_slots == q4["up_slots"]
    assert plan.uplink_bits(False) == q4["up_bits"]


# ---------------------------------------------------------------------------
# Codec round trips (property tests over bits in {4, 8, 16})
# ---------------------------------------------------------------------------

def _table(key, d=4, c=10):
    t = jax.random.uniform(key, (d, c, c))
    return t / jnp.sum(t, axis=-1, keepdims=True)  # rows are soft labels


@pytest.mark.parametrize("bits", [4, 8, 16])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_quantize_round_trip_within_grid_step(bits, seed):
    spec = parse_codec("quantize", quant_bits=bits)
    key = jax.random.PRNGKey(seed)
    favg = _table(jax.random.fold_in(key, 0))
    ref = _table(jax.random.fold_in(key, 1))
    _, rx = uplink_stage(spec, "fd", None, favg, key, ref, None)
    # stochastic rounding moves a [0,1] value at most one grid step
    assert float(jnp.max(jnp.abs(rx - favg))) <= 1.0 / spec.levels + 1e-7
    assert float(jnp.min(rx)) >= 0.0 and float(jnp.max(rx)) <= 1.0


@pytest.mark.parametrize("bits", [4, 8, 16])
def test_quantize_is_unbiased(bits):
    spec = parse_codec("quantize", quant_bits=bits)
    favg = _table(jax.random.PRNGKey(3))
    outs = [uplink_stage(spec, "fd", None, favg,
                         jax.random.PRNGKey(100 + i), favg, None)[1]
            for i in range(64)]
    err = float(jnp.max(jnp.abs(jnp.mean(jnp.stack(outs), 0) - favg)))
    # E[round(x)] = x: the mean over keys converges well inside a step
    assert err < 1.0 / spec.levels


@pytest.mark.parametrize("seed", [0, 1])
def test_delta_codec_round_trips_exactly(seed):
    spec = parse_codec("delta")
    key = jax.random.PRNGKey(seed)
    favg = _table(jax.random.fold_in(key, 0))
    ref = _table(jax.random.fold_in(key, 1))
    _, rx = uplink_stage(spec, "fd", None, favg, key, ref, None)
    np.testing.assert_allclose(np.asarray(rx), np.asarray(favg),
                               atol=1e-6)


def test_identity_stage_is_a_bitwise_passthrough():
    spec = parse_codec("identity")
    favg = _table(jax.random.PRNGKey(0))
    params = {"w": jnp.ones((4, 3, 2))}
    dp, rx = uplink_stage(spec, "mix2fld", params, favg,
                          jax.random.PRNGKey(1), favg, None)
    assert rx is favg and dp is params  # the very same arrays, no ops


def test_dp_gaussian_clips_to_sensitivity():
    key = jax.random.PRNGKey(0)
    x = 100.0 * jax.random.normal(key, (32,))
    out = gaussian_mechanism(x, key, sigma=1e-6, clip=1.0)
    assert float(jnp.linalg.norm(out)) <= 1.0 + 1e-3  # clip + tiny noise


# ---------------------------------------------------------------------------
# DP accountant: monotone in rounds, closed-form epsilon
# ---------------------------------------------------------------------------

def test_accountant_epsilon_monotone_and_closed_form():
    import math
    sigma, delta = 1.2, 1e-5
    acct = GaussianAccountant(sigma, delta)
    eps0 = math.sqrt(2.0 * math.log(1.25 / delta)) / sigma
    prev = 0.0
    for t in range(1, 8):
        acct.step()
        eps = acct.epsilon()
        assert eps > prev                       # strictly monotone
        assert abs(eps - t * eps0) < 1e-12      # closed-form composition
        assert abs(eps - gaussian_epsilon(sigma, delta, t)) < 1e-12
        prev = eps
    led = acct.ledger()
    assert led["rounds"] == 7 and abs(led["epsilon"] - prev) < 1e-12


def test_accountant_rejects_bad_parameters():
    with pytest.raises(ValueError, match="sigma > 0"):
        GaussianAccountant(0.0)
    with pytest.raises(ValueError, match="delta"):
        GaussianAccountant(1.0, delta=2.0)


# ---------------------------------------------------------------------------
# Downlink stages: one function, both layouts
# ---------------------------------------------------------------------------

def test_downlink_stages_match_on_loop_and_grid_layouts():
    key = jax.random.PRNGKey(7)
    G, D, C = 3, 4, 5
    dev_gout = jax.random.uniform(jax.random.fold_in(key, 0), (G, D, C, C))
    gout = jax.random.uniform(jax.random.fold_in(key, 1), (G, C, C))
    dn_ok = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (G, D))
    grid = downlink_gout(dev_gout, gout, dn_ok)
    for g in range(G):
        loop = downlink_gout(dev_gout[g], gout[g], dn_ok[g])
        np.testing.assert_array_equal(np.asarray(grid[g]),
                                      np.asarray(loop))
    dev_p = {"w": jax.random.uniform(jax.random.fold_in(key, 3),
                                     (G, D, 2, 3))}
    g_p = {"w": jax.random.uniform(jax.random.fold_in(key, 4), (G, 2, 3))}
    gridp = downlink_params(dev_p, g_p, dn_ok)
    for g in range(G):
        loopp = downlink_params({"w": dev_p["w"][g]}, {"w": g_p["w"][g]},
                                dn_ok[g])
        np.testing.assert_array_equal(np.asarray(gridp["w"][g]),
                                      np.asarray(loopp["w"]))
