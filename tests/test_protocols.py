"""End-to-end protocol tests: FL / FD / FLD / MixFLD / Mix2FLD on the
paper's CNN with synthetic data (reduced iteration counts for CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import ChannelConfig
from repro.core.protocols import PROTOCOLS, FederatedConfig, FederatedTrainer
from repro.data import partition_iid, partition_noniid, synthetic_images
from repro.models.cnn import CNN


@pytest.fixture(scope="module")
def data():
    key = jax.random.PRNGKey(0)
    x, y = synthetic_images(key, 4000)
    dev_x, dev_y = partition_iid(x[:3000], y[:3000], 5, 400, 10)
    return dev_x, dev_y, jnp.asarray(x[3000:]), jnp.asarray(y[3000:])


def _cfg(protocol, **kw):
    base = dict(protocol=protocol, num_devices=5, local_iters=60,
                local_batch=32, server_iters=60, server_batch=32,
                max_rounds=3, n_seed=10, n_inverse=20, seed=0)
    base.update(kw)
    return FederatedConfig(**base)


# symmetric channel so every protocol actually trains in 3 rounds
SYM = ChannelConfig(num_devices=5, p_up_dbm=40.0)


@pytest.mark.slow
@pytest.mark.parametrize("protocol", PROTOCOLS)
def test_protocol_runs_and_learns(protocol, data):
    dev_x, dev_y, tx, ty = data
    tr = FederatedTrainer(CNN(), _cfg(protocol), SYM)
    h = tr.run(dev_x, dev_y, tx, ty)
    assert len(h["acc"]) == 3
    assert all(np.isfinite(a) for a in h["acc"])
    assert h["acc"][-1] > 0.15  # better than chance after 3 rounds
    assert h["cum_time_s"][-1] > 0


@pytest.mark.slow
def test_mix2fld_seed_set_has_hard_labels_and_augments(data):
    dev_x, dev_y, tx, ty = data
    tr = FederatedTrainer(CNN(), _cfg("mix2fld", keep_seed_arrays=True), SYM)
    h = tr.run(dev_x, dev_y, tx, ty)
    meta = h["seeds"]
    assert meta["hard_labels"]  # hard labels after inverse-Mixup
    # N_I >= N_S: augmentation property (Sec. III-C)
    assert meta["n_train"] >= meta["n_uploaded"]
    seeds = h["seed_arrays"]  # opt-in full arrays agree with the summary
    assert seeds["train_y"].ndim == 1
    assert seeds["train_x"].shape[0] == meta["n_train"]
    assert seeds["uploaded"].shape[0] == meta["n_uploaded"]


@pytest.mark.slow
def test_mixfld_uploads_soft_labels(data):
    dev_x, dev_y, tx, ty = data
    tr = FederatedTrainer(CNN(), _cfg("mixfld", keep_seed_arrays=True), SYM)
    h = tr.run(dev_x, dev_y, tx, ty)
    assert not h["seeds"]["hard_labels"]
    seeds = h["seed_arrays"]
    assert seeds["train_y"].ndim == 2  # soft labels
    np.testing.assert_allclose(np.asarray(seeds["train_y"].sum(-1)), 1.0,
                               atol=1e-5)


def test_history_seeds_is_lightweight_metadata(golden_data):
    """By default histories carry JSON-ready seed metadata (counts, pair
    count, cycle-length histogram), not device arrays — serialized
    benchmark results stay small; arrays are opt-in."""
    import json
    dev_x, dev_y, tx, ty = golden_data
    tr = FederatedTrainer(CNN(), _golden_cfg("mix2fld", max_rounds=1),
                          GOLDEN_CH)
    h = tr.run(dev_x, dev_y, tx, ty)
    assert "seed_arrays" not in h
    meta = h["seeds"]
    assert json.loads(json.dumps(meta))["n_train"] == meta["n_train"]
    assert meta["n_uploaded"] == 4 * 6  # D * n_seed
    assert meta["n_pairs"] >= 1
    assert meta["hard_labels"]
    # pair entries count as length-2 cycles in the histogram; keys are
    # strings so the dict is identical after a JSON round-trip
    assert meta["cycle_hist"].get("2") == meta["n_pairs"]
    assert sum(int(k) * v for k, v in meta["cycle_hist"].items()) >= \
        2 * meta["n_pairs"]


def test_mix2up_privacy_exceeds_mixup_privacy(data):
    """Table III vs Table II: inversely mixed-up samples are farther from
    their raw constituents than plain mixed-up uploads."""
    from repro.core.privacy import mean_privacy
    dev_x, dev_y, tx, ty = data
    fc = _cfg("mix2fld", lam=0.4)
    tr = FederatedTrainer(CNN(), fc, SYM)
    seeds = tr.collect_seeds(jnp.asarray(dev_x), jnp.asarray(dev_y),
                             jax.random.PRNGKey(7))
    p_mixup = mean_privacy(seeds["uploaded"], seeds["raw_pairs"])
    # Mix2up samples vs the raws of *their* constituents is what Table III
    # reports; conservatively compare against all uploaded raws pairwise
    n = min(seeds["train_x"].shape[0], seeds["raw_pairs"].shape[0])
    p_mix2 = mean_privacy(seeds["train_x"][:n], seeds["raw_pairs"][:n])
    assert p_mix2 > p_mixup - 0.5  # never catastrophically worse


def test_noniid_partition_matches_paper_recipe():
    key = jax.random.PRNGKey(1)
    x, y = synthetic_images(key, 8000)
    dev_x, dev_y = partition_noniid(x, y, 10)
    assert dev_x.shape[0] == 10
    for d in range(10):
        counts = np.bincount(dev_y[d], minlength=10)
        assert sorted(counts)[:2] == [2, 2]          # two rare labels
        assert all(c == 62 for c in sorted(counts)[2:])  # rest 62 each
        assert counts.sum() == 500


@pytest.mark.slow
def test_fd_uses_kd_after_first_round(data):
    """FD devices keep their own weights; accuracy should keep rising."""
    dev_x, dev_y, tx, ty = data
    tr = FederatedTrainer(CNN(), _cfg("fd", max_rounds=4), SYM)
    h = tr.run(dev_x, dev_y, tx, ty)
    assert h["acc"][-1] > h["acc"][0]


def test_collect_seeds_batched_invariants(data):
    """The device-axis-batched pipeline keeps the old path's guarantees:
    uploaded set is (D*Ns, ...), inverse set has hard labels in range,
    pairing produced cross-device symmetric pairs, and the inverse set
    meets the N_I augmentation target."""
    dev_x, dev_y, _, _ = data
    fc = _cfg("mix2fld")
    tr = FederatedTrainer(CNN(), fc, SYM)
    seeds = tr.collect_seeds(jnp.asarray(dev_x), jnp.asarray(dev_y),
                             jax.random.PRNGKey(3))
    D, Ns = fc.num_devices, fc.n_seed
    assert seeds["uploaded"].shape[0] == D * Ns
    assert seeds["raw_pairs"].shape[:2] == (D * Ns, 2)
    assert seeds["train_x"].shape[0] == fc.n_inverse * D
    assert seeds["train_x"].shape[1:] == seeds["uploaded"].shape[1:]
    assert seeds["train_y"].ndim == 1
    y = np.asarray(seeds["train_y"])
    assert y.min() >= 0 and y.max() < fc.num_classes
    assert seeds["n_pairs"] > 0


def test_collect_seeds_lam_half_degrades_to_soft_labels(data):
    """lam = 0.5 makes Prop. 1 singular; the pipeline must fall back to
    soft-label (MixFLD-style) training instead of dividing by zero."""
    dev_x, dev_y, _, _ = data
    tr = FederatedTrainer(CNN(), _cfg("mix2fld", lam=0.5), SYM)
    seeds = tr.collect_seeds(jnp.asarray(dev_x), jnp.asarray(dev_y),
                             jax.random.PRNGKey(5))
    assert seeds["train_y"].ndim == 2  # soft labels
    assert bool(jnp.isfinite(seeds["train_x"]).all())


def test_collect_seeds_fld_draws_without_replacement(data):
    dev_x, dev_y, _, _ = data
    fc = _cfg("fld")
    tr = FederatedTrainer(CNN(), fc, SYM)
    seeds = tr.collect_seeds(jnp.asarray(dev_x), jnp.asarray(dev_y),
                             jax.random.PRNGKey(4))
    assert seeds["train_x"].shape[0] == fc.num_devices * fc.n_seed
    assert seeds["train_y"].shape == (fc.num_devices * fc.n_seed,)


def test_collect_seeds_fld_rejects_seed_budget_above_local_data():
    """n_seed > n_local used to surface as an opaque JAX error from
    ``random.choice(..., replace=False)``; it must be a clear ValueError
    at the seed-prep boundary."""
    from repro.core.protocols import collect_seeds
    key = jax.random.PRNGKey(0)
    dev_x = jax.random.normal(key, (3, 8, 28, 28, 1))  # n_local = 8
    dev_y = jax.random.randint(key, (3, 8), 0, 10)
    fc = FederatedConfig(protocol="fld", num_devices=3, n_seed=9)
    with pytest.raises(ValueError, match="without replacement"):
        collect_seeds(fc, dev_x, dev_y, key)
    # the mixup paths' equivalent bound: pairs need >= 2 local samples
    tiny_x, tiny_y = dev_x[:, :1], dev_y[:, :1]
    fc2 = FederatedConfig(protocol="mix2fld", num_devices=3, n_seed=1,
                          n_inverse=1)
    with pytest.raises(ValueError, match="at least 2 local samples"):
        collect_seeds(fc2, tiny_x, tiny_y, key)


def test_federated_config_validates_fields():
    with pytest.raises(ValueError, match="unknown protocol"):
        FederatedConfig(protocol="nonsense")
    with pytest.raises(ValueError, match="n_seed"):
        FederatedConfig(n_seed=0)
    with pytest.raises(ValueError, match="n_inverse"):
        FederatedConfig(n_inverse=0)
    with pytest.raises(ValueError, match="lam"):
        FederatedConfig(lam=1.5)


# ---------------------------------------------------------------------------
# Fixed-seed regression goldens + sharded-vs-vmapped equivalence (fast
# configs: these run in the tier-1 suite and lock the round loop down)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def golden_data():
    x, y = synthetic_images(jax.random.PRNGKey(42), 1400)
    dev_x, dev_y = partition_iid(np.asarray(x[:1200]), np.asarray(y[:1200]),
                                 4, 300, 10, seed=0)
    return dev_x, dev_y, jnp.asarray(x[1200:]), jnp.asarray(y[1200:])


def _golden_cfg(protocol, **kw):
    base = dict(protocol=protocol, num_devices=4, local_iters=8,
                local_batch=16, server_iters=8, server_batch=16,
                max_rounds=3, n_seed=6, n_inverse=12, seed=0)
    base.update(kw)
    return FederatedConfig(**base)


GOLDEN_CH = ChannelConfig(num_devices=4, p_up_dbm=40.0)

# 3-round histories recorded when the sharded round loop / Pallas hot
# path landed; if an *intentional* numerics change lands, regenerate with
# the snippet in docs/sharded_round_loop.md §Regression goldens.
# mix2fld re-recorded when the segment/sort cycle search replaced the
# budgeted DFS (higher cycle yield changes the round-1 inverse set).
GOLDEN = {
    "fl": dict(
        acc=[0.075, 0.125, 0.285],
        loss=[2.324292, 2.29544, 2.267828],
        latency_s=[0.062, 0.06, 0.062]),
    "fd": dict(
        acc=[0.11, 0.105, 0.14],
        loss=[2.324292, 2.31746, 2.294407],
        latency_s=[0.002, 0.002, 0.002]),
    "fld": dict(
        acc=[0.12, 0.12, 0.13],
        loss=[2.324292, 2.32959, 2.335337],
        latency_s=[0.027, 0.021, 0.022]),
    "mixfld": dict(
        acc=[0.105, 0.095, 0.095],
        loss=[2.324292, 2.37006, 2.356361],
        latency_s=[0.027, 0.021, 0.022]),
    "mix2fld": dict(
        acc=[0.09, 0.215, 0.14],
        loss=[2.324292, 2.38605, 2.403923],
        latency_s=[0.027, 0.021, 0.022]),
}


@pytest.mark.parametrize("protocol", sorted(GOLDEN))
def test_protocol_golden_history(protocol, golden_data):
    """Fixed-seed 3-round histories must reproduce the recorded goldens:
    catches silent numerics drift anywhere on the round loop (local SGD,
    kernels, aggregation, channel, conversion)."""
    dev_x, dev_y, tx, ty = golden_data
    tr = FederatedTrainer(CNN(), _golden_cfg(protocol), GOLDEN_CH)
    h = tr.run(dev_x, dev_y, tx, ty)
    want = GOLDEN[protocol]
    np.testing.assert_allclose(h["acc"], want["acc"], atol=1e-4)
    np.testing.assert_allclose(h["loss"], want["loss"], atol=1e-4)
    np.testing.assert_allclose(h["round_latency_s"], want["latency_s"],
                               rtol=1e-6)


@pytest.mark.parametrize("protocol", ["fd", "mix2fld"])
def test_sharded_round_loop_matches_vmapped(protocol, golden_data):
    """shard_devices=True on a 1-chip mesh must reproduce the vmapped
    path's fixed-seed history within 1e-4 (the psum collectives reduce to
    the tensordot/einsum reductions when there is one shard)."""
    dev_x, dev_y, tx, ty = golden_data
    tr_v = FederatedTrainer(CNN(), _golden_cfg(protocol), GOLDEN_CH)
    h_v = tr_v.run(dev_x, dev_y, tx, ty)
    tr_s = FederatedTrainer(CNN(), _golden_cfg(protocol, shard_devices=True),
                            GOLDEN_CH)
    assert tr_s.mesh is not None and tr_v.mesh is None
    h_s = tr_s.run(dev_x, dev_y, tx, ty)
    np.testing.assert_allclose(h_s["acc"], h_v["acc"], atol=1e-4)
    np.testing.assert_allclose(h_s["loss"], h_v["loss"], atol=1e-4)
    assert h_s["round_latency_s"] == h_v["round_latency_s"]
    assert h_s["converged_round"] == h_v["converged_round"]
    np.testing.assert_allclose(np.asarray(tr_s.last_dev_gout),
                               np.asarray(tr_v.last_dev_gout), atol=1e-5)


@pytest.mark.multichip
def test_sharded_round_loop_multichip_really_shards(golden_data):
    """Pod validation (auto-skipped on 1-chip hosts): with >1 chip the
    device mesh must actually split the population, and the psum
    round loop must still match the vmapped oracle."""
    dev_x, dev_y, tx, ty = golden_data
    tr_s = FederatedTrainer(CNN(), _golden_cfg("mix2fld",
                                               shard_devices=True),
                            GOLDEN_CH)
    assert tr_s.mesh.devices.size > 1
    h_s = tr_s.run(dev_x, dev_y, tx, ty)
    tr_v = FederatedTrainer(CNN(), _golden_cfg("mix2fld"), GOLDEN_CH)
    h_v = tr_v.run(dev_x, dev_y, tx, ty)
    np.testing.assert_allclose(h_s["acc"], h_v["acc"], atol=1e-4)
    np.testing.assert_allclose(h_s["loss"], h_v["loss"], atol=1e-4)
    np.testing.assert_allclose(np.asarray(tr_s.last_dev_gout),
                               np.asarray(tr_v.last_dev_gout), atol=1e-5)


def test_sharded_mesh_auto_shard_count():
    """make_device_mesh picks the largest divisor of |D| that fits the
    local chip count, and rejects non-divisible explicit counts."""
    from repro.launch.mesh import make_device_mesh
    mesh = make_device_mesh(10)
    assert mesh.axis_names == ("data",)
    assert 10 % mesh.devices.size == 0
    with pytest.raises(ValueError):
        make_device_mesh(10, shards=3)


# SNR target no link can meet: every uplink AND downlink outages, so the
# global state never changes after round 1 — the spurious-convergence trap
ALL_OUT = ChannelConfig(num_devices=4, theta=1e9)


@pytest.mark.parametrize("protocol", ["fl", "fd", "mix2fld"])
def test_total_outage_rounds_never_record_convergence(protocol,
                                                      golden_data):
    """Regression: with every uplink failing, g_params/gout stay frozen,
    rel == 0 < eps, and the old check recorded converged_round = 2 on a
    round where *nothing arrived*.  The check must be gated on at least
    one decoded uplink."""
    dev_x, dev_y, tx, ty = golden_data
    fc = _golden_cfg(protocol, eps=10.0)  # any rel passes the threshold
    tr = FederatedTrainer(CNN(), fc, ALL_OUT)
    h = tr.run(dev_x, dev_y, tx, ty)
    assert h["uplink_ok"] == [0, 0, 0]
    assert h["converged_round"] is None


def test_convergence_still_fires_when_uplinks_decode(golden_data):
    """Control for the outage gate: same eps on a clean channel records
    the first checkable round as before."""
    dev_x, dev_y, tx, ty = golden_data
    tr = FederatedTrainer(CNN(), _golden_cfg("fd", eps=10.0), GOLDEN_CH)
    h = tr.run(dev_x, dev_y, tx, ty)
    assert all(n > 0 for n in h["uplink_ok"])
    assert h["converged_round"] == 2


def test_round_once_resume_matches_uninterrupted_run(golden_data):
    """The factored step is genuinely resumable: running rounds 1..3
    through a fresh state object round-by-round — with a full state
    hand-off between rounds, as the serving driver does across process
    restarts — reproduces run()'s history bit-for-bit."""
    dev_x, dev_y, tx, ty = golden_data
    tr = FederatedTrainer(CNN(), _golden_cfg("mix2fld"), GOLDEN_CH)
    h = tr.run(dev_x, dev_y, tx, ty)
    tr2 = FederatedTrainer(CNN(), _golden_cfg("mix2fld"), GOLDEN_CH)
    state = tr2.init_state()
    recs = []
    for _ in range(3):
        # rebuild the dict each round: nothing may depend on object
        # identity carrying over (a restore produces fresh arrays)
        state = dict(state)
        state, rec = tr2.round_once(state, dev_x, dev_y, tx, ty)
        recs.append(rec)
    assert [r["acc"] for r in recs] == h["acc"]
    assert [r["loss"] for r in recs] == h["loss"]
    assert [r["round_latency_s"] for r in recs] == h["round_latency_s"]
    assert [r["uplink_ok"] for r in recs] == h["uplink_ok"]
    assert state["converged_round"] == h["converged_round"]


# downlink that never decodes (p_dn far below the SNR target) vs always
NO_DN = ChannelConfig(num_devices=5, p_up_dbm=40.0, p_dn_dbm=-60.0)


def test_fd_downlink_gating_keeps_previous_gout(data):
    """A device whose downlink failed must keep its previous G_out rather
    than receiving the new one for free."""
    dev_x, dev_y, tx, ty = data
    fc = _cfg("fd", max_rounds=2, local_iters=10)
    tr = FederatedTrainer(CNN(), fc, NO_DN)
    tr.run(dev_x, dev_y, tx, ty)
    C = fc.num_classes
    # every downlink outages => all devices still hold the uniform prior
    np.testing.assert_allclose(np.asarray(tr.last_dev_gout),
                               np.full((5, C, C), 1.0 / C), atol=1e-6)
    # control: with a clean downlink the tables are refreshed
    tr2 = FederatedTrainer(CNN(), fc, SYM)
    tr2.run(dev_x, dev_y, tx, ty)
    assert float(np.abs(np.asarray(tr2.last_dev_gout) - 1.0 / C).max()) > 1e-3
