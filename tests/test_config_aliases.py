"""Deprecation-alias round-trips for the typed-config redesign.

``FederatedConfig``'s flat sampling/codec fields became typed
sub-configs (``sampler``/``churn``/``channel``); the flat fields stay as
deprecation-warning aliases for one release.  These tests pin the
reconciliation contract of ``FederatedConfig._sync_sub`` — either
surface constructs the same config, the aliases always mirror the sub,
and the sweep's ``dataclasses.replace`` mutation path keeps working —
plus the matching transitional surfaces on :class:`RoundState` and
``SamplerConfig.__call__``.
"""
import dataclasses
import warnings

import jax.numpy as jnp
import pytest

from repro.channel.payload import LinkConfig
from repro.core.protocols import FederatedConfig
from repro.core.sampling import ChurnConfig, SamplerConfig
from repro.core.state import RoundState


def _fc(**kw):
    return FederatedConfig(protocol="fd", num_devices=4, **kw)


# ---------------------------------------------------------------------------
# Flat aliases -> sub-config (the legacy kwargs path)
# ---------------------------------------------------------------------------

def test_flat_sampler_kwargs_build_sub_and_warn():
    with pytest.warns(DeprecationWarning, match="sample_ratio"):
        fc = _fc(sample_ratio=0.5, sample_seed=3, sample_min_active=2)
    assert fc.sampler == SamplerConfig(sample_ratio=0.5, seed=3,
                                       min_active=2)
    # the aliases mirror the sub after construction
    assert fc.sample_ratio == 0.5
    assert fc.sample_seed == 3
    assert fc.cohort_size() == 2


def test_flat_codec_kwargs_build_sub_and_warn():
    with pytest.warns(DeprecationWarning, match="quant_bits"):
        fc = _fc(codec="quantize", quant_bits=4)
    assert fc.channel == LinkConfig(codec="quantize", quant_bits=4)
    assert fc.codec_spec().name == "quantize"
    assert fc.codec_spec().quant_bits == 4


def test_defaults_warn_nothing():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        fc = _fc()
    assert fc.sampler == SamplerConfig()
    assert fc.channel == LinkConfig()
    assert fc.churn is None


# ---------------------------------------------------------------------------
# Sub-config -> flat aliases (the canonical path)
# ---------------------------------------------------------------------------

def test_sub_config_syncs_flat_aliases_silently():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        fc = _fc(sampler=SamplerConfig(sample_ratio=0.5, seed=3),
                 channel=LinkConfig(codec="quantize", quant_bits=4))
    # legacy readers (getattr on the flat names) see live values
    assert fc.sample_ratio == 0.5
    assert fc.sample_seed == 3
    assert fc.codec == "quantize"
    assert fc.quant_bits == 4


def test_both_surfaces_agree_either_way():
    with pytest.warns(DeprecationWarning):
        via_flat = _fc(sample_ratio=0.5, quant_bits=4, codec="quantize")
    via_sub = _fc(sampler=SamplerConfig(sample_ratio=0.5),
                  channel=LinkConfig(codec="quantize", quant_bits=4))
    assert via_flat.sampler == via_sub.sampler
    assert via_flat.channel == via_sub.channel
    assert via_flat.cohort_size() == via_sub.cohort_size()


def test_flats_win_on_disagreement():
    """``dataclasses.replace(fc, sample_ratio=q)`` hands the old sub
    plus the new flat value — the flat edit must take effect (this is
    the sweep axis mutation surface)."""
    fc = _fc(sampler=SamplerConfig(sample_ratio=0.5, seed=3))
    fc2 = dataclasses.replace(fc, sample_ratio=0.25)
    assert fc2.sampler.sample_ratio == 0.25
    assert fc2.sample_ratio == 0.25
    # untouched alias groups survive the replace
    assert fc2.sample_seed == 3
    assert fc2.channel == fc.channel


def test_replace_preserves_sub_configs():
    fc = _fc(sampler=SamplerConfig(sample_ratio=0.5),
             channel=LinkConfig(codec="quantize", quant_bits=4),
             churn=ChurnConfig(p_active=0.75))
    fc2 = dataclasses.replace(fc, eta=0.02)
    assert fc2.sampler == fc.sampler
    assert fc2.channel == fc.channel
    assert fc2.churn == fc.churn


# ---------------------------------------------------------------------------
# Validation funnels through the sub-configs
# ---------------------------------------------------------------------------

def test_validation_lives_in_sub_configs():
    with pytest.raises(ValueError, match="sample_ratio"):
        SamplerConfig(sample_ratio=0.0)
    with pytest.raises(ValueError, match="sample_ratio"):
        _fc(sample_ratio=1.5)
    with pytest.raises(ValueError, match="p_active"):
        ChurnConfig(p_active=0.0)
    with pytest.raises(ValueError):
        LinkConfig(codec="no_such_codec")
    with pytest.raises(ValueError):
        _fc(codec="no_such_codec")
    with pytest.raises(TypeError, match="ChurnConfig"):
        _fc(churn={"p_active": 0.5})


def test_sampler_call_is_transitional_noop():
    fc = _fc()
    assert fc.sampler() is fc.sampler
    assert fc.sampler().cohort_size(4) == 4


# ---------------------------------------------------------------------------
# RoundState transitional mapping surface
# ---------------------------------------------------------------------------

def test_round_state_mapping_compat():
    st = RoundState(round=3, key=jnp.zeros((2,), jnp.uint32),
                    converged_round=2)
    assert st["round"] == 3
    assert st["converged"] == 2          # historical grid-carry key
    assert st.get("prev") is None
    assert st.get("no_such_field", 7) == 7
    assert "converged" in st and "round" in st
    assert set(st.keys()) == {
        "round", "key", "g_params", "dev_params", "gout", "dev_gout",
        "prev", "converged_round", "seeds", "cum_time_s"}
    assert dict(zip(st, [st[k] for k in st]))["round"] == 3


def test_round_state_from_mapping_round_trips():
    st = RoundState(round=3, cum_time_s=1.5, converged_round=2)
    assert RoundState.from_mapping(st) is st
    again = RoundState.from_mapping(
        {"round": 3, "cum_time_s": 1.5, "converged": 2})
    assert again == st
    assert st.replace(converged=4).converged_round == 4
    with pytest.raises(ValueError, match="unknown RoundState field"):
        RoundState.from_mapping({"round": 3, "not_a_field": 1})
