"""Property tests (hypothesis) for checkpoint save/restore round-trips.

Skipped entirely when ``hypothesis`` is not installed (install the
``test`` extra); deterministic equivalents of the core round-trip /
mismatch behaviors always run in ``test_checkpoint.py``.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import checkpoint as ckpt  # noqa: E402

_KEYS = st.text(alphabet="abcdefgh", min_size=1, max_size=4)
_SHAPES = st.lists(st.integers(1, 4), min_size=0, max_size=3).map(tuple)
_DTYPES = st.sampled_from([np.float32, np.int32, np.uint32, np.float64])


@st.composite
def leaves(draw):
    shape = draw(_SHAPES)
    dtype = draw(_DTYPES)
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    vals = draw(st.lists(
        st.integers(-1000, 1000), min_size=n, max_size=n))
    return np.asarray(vals, dtype=dtype).reshape(shape)


def trees(depth=2):
    leaf = leaves()
    if depth == 0:
        return leaf
    return st.dictionaries(_KEYS, st.one_of(leaf, trees(depth - 1)),
                           min_size=1, max_size=3)


@given(tree=trees(), step=st.integers(0, 10**7))
@settings(max_examples=30, deadline=None)
def test_save_restore_roundtrip(tmp_path_factory, tree, step):
    d = str(tmp_path_factory.mktemp("ck"))
    ckpt.save(d, step, tree)
    assert ckpt.latest_step(d) == step
    zeros = {}  # restore_tree needs no template — compare straight
    del zeros
    out, _ = ckpt.restore_tree(d)
    flat_in = ckpt._flatten_with_paths(tree)
    flat_out = ckpt._flatten_with_paths(out)
    assert flat_in[0] == flat_out[0]
    for a, b in zip(flat_in[1], flat_out[1]):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)


@given(tree=trees(), step=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_restore_into_zeroed_template(tmp_path_factory, tree, step):
    import jax

    d = str(tmp_path_factory.mktemp("ck"))
    ckpt.save(d, step, tree)
    template = jax.tree.map(np.zeros_like, tree)
    out = ckpt.restore(d, template)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(tree=st.dictionaries(_KEYS, leaves(), min_size=2, max_size=4),
       data=st.data())
@settings(max_examples=20, deadline=None)
def test_renamed_leaf_always_raises(tmp_path_factory, tree, data):
    d = str(tmp_path_factory.mktemp("ck"))
    ckpt.save(d, 1, tree)
    old = data.draw(st.sampled_from(sorted(tree)))
    bad = dict(tree)
    bad[old + "_renamed"] = bad.pop(old)
    with pytest.raises(ValueError):
        ckpt.restore(d, bad)
