"""Wireless channel model tests (Sec. II-C, eq. 4)."""
import math

import jax
import numpy as np

from repro.channel import ChannelConfig, payload_bits, round_trip
from repro.channel.model import simulate_link


def test_link_budget_success_probability():
    cfg = ChannelConfig()
    p_up, bits_up = cfg.link_budget(up=True)
    # analytic: mean SNR = P r^-a / (W_up N0); p = exp(-theta/meanSNR)
    w_up = cfg.bandwidth_hz * cfg.num_channels / cfg.num_devices
    p_tx = 10 ** ((cfg.p_up_dbm - 30) / 10)
    n0 = 10 ** ((cfg.noise_dbm_hz - 30) / 10)
    mean_snr = p_tx * cfg.distance_m ** -cfg.pathloss_exp / (w_up * n0)
    assert math.isclose(p_up, math.exp(-cfg.theta / mean_snr), rel_tol=1e-9)
    assert math.isclose(bits_up, cfg.tau_s * w_up * math.log2(1 + cfg.theta),
                        rel_tol=1e-9)


def test_empirical_success_rate_matches_analytic():
    cfg = ChannelConfig()
    p, bits = cfg.link_budget(up=True)
    # payload of exactly 1 good slot: success within T_max ~ 1-(1-p)^T
    lat, ok = simulate_link(jax.random.PRNGKey(0), cfg, bits, True, 4000)
    want = 1 - (1 - p) ** cfg.t_max_slots
    got = float(np.mean(np.asarray(ok)))
    assert abs(got - want) < 0.02


def test_fl_uplink_payload_exceeds_asymmetric_capacity():
    """The paper's exact numbers put FL's uplink payload (32 x 12,544 =
    401,408 bits) just above the T_max uplink capacity (400,000 bits) —
    FL deterministically outages on the asymmetric uplink, which is the
    letter's motivating regime (EXPERIMENTS.md discusses the boundary)."""
    cfg = ChannelConfig()
    up_bits, _ = payload_bits("fl", n_mod=12544, n_labels=10)
    _, bits_per_slot = cfg.link_budget(up=True)
    assert up_bits > bits_per_slot * cfg.t_max_slots
    lat, ok = simulate_link(jax.random.PRNGKey(1), cfg, up_bits, True, 256)
    assert not bool(np.any(np.asarray(ok)))


def test_fd_payload_much_smaller_than_fl():
    up_fl, dn_fl = payload_bits("fl", n_mod=12544, n_labels=10)
    up_fd, dn_fd = payload_bits("fd", n_mod=12544, n_labels=10)
    assert up_fd == 32 * 10 * 10
    assert up_fl / up_fd > 100  # orders of magnitude (paper: "up to 42.4x")


def test_fld_first_round_includes_seed_samples():
    up1, dn1 = payload_bits("mix2fld", n_mod=12544, n_labels=10,
                            sample_bits=6272, n_seed=10, first_round=True)
    up2, dn2 = payload_bits("mix2fld", n_mod=12544, n_labels=10,
                            sample_bits=6272, n_seed=10, first_round=False)
    assert up1 - up2 == 6272 * 10
    assert dn1 == dn2 == 32 * 12544  # downlink carries the model (FL-style)


def test_round_trip_masks_and_latency():
    cfg = ChannelConfig(num_devices=8)
    out = round_trip(jax.random.PRNGKey(2), cfg, 3200, 3200)
    assert out["up_ok"].shape == (8,)
    assert out["latency_s"] <= 2 * cfg.t_max_slots * cfg.tau_s + 1e-9
    assert out["latency_s"] > 0


def test_round_trip_latency_waits_on_slowest_successful_only(monkeypatch):
    """Regression for the outage-latency bug: outage links are pinned at
    t_max_slots and must NOT inflate round latency; only the slowest
    *successful* link in each direction counts."""
    import jax.numpy as jnp

    from repro.channel import model as chmod

    cfg = ChannelConfig(num_devices=3)
    crafted = {
        # one uplink outage pinned at t_max; slowest success takes 7 slots
        True: (jnp.array([3, cfg.t_max_slots, 7]),
               jnp.array([True, False, True])),
        # one downlink outage; slowest success takes 2 slots
        False: (jnp.array([2, 2, cfg.t_max_slots]),
                jnp.array([True, True, False])),
    }
    monkeypatch.setattr(chmod, "simulate_link",
                        lambda key, c, bits, up, n: crafted[up])
    out = chmod.round_trip(jax.random.PRNGKey(0), cfg, 1.0, 1.0)
    # buggy semantics charged tau * (100 + 100); fixed: tau * (7 + 2)
    assert math.isclose(out["latency_s"], cfg.tau_s * (7 + 2), rel_tol=1e-9)


def test_round_trip_latency_recompute_from_masks():
    """The reported latency always equals the mask-filtered recompute from
    the per-link outputs, whatever the draw."""
    cfg = ChannelConfig(num_devices=64)
    p, bits = cfg.link_budget(up=True)
    up_bits = bits * max(1, round(cfg.t_max_slots * p))
    out = round_trip(jax.random.PRNGKey(11), cfg, up_bits, bits)
    t_up, ok_up = np.asarray(out["t_up"]), np.asarray(out["up_ok"])
    t_dn, ok_dn = np.asarray(out["t_dn"]), np.asarray(out["dn_ok"])
    want_up = t_up[ok_up].max() if ok_up.any() else cfg.t_max_slots
    want_dn = t_dn[ok_dn].max() if ok_dn.any() else cfg.t_max_slots
    assert math.isclose(out["latency_s"],
                        cfg.tau_s * (float(want_up) + float(want_dn)),
                        rel_tol=1e-9)


def test_round_trip_all_outage_falls_back_to_t_max():
    cfg = ChannelConfig()
    p, bits = cfg.link_budget(up=True)
    huge = bits * cfg.t_max_slots * 10  # cannot fit in the window
    out = round_trip(jax.random.PRNGKey(5), cfg, huge, bits)
    assert not bool(np.any(np.asarray(out["up_ok"])))
    dn_ok = np.asarray(out["dn_ok"])
    t_dn = np.asarray(out["t_dn"])
    want_dn = t_dn[dn_ok].max() if dn_ok.any() else cfg.t_max_slots
    assert math.isclose(out["latency_s"],
                        cfg.tau_s * (cfg.t_max_slots + float(want_dn)),
                        rel_tol=1e-9)


def test_compute_outcomes_statistics_and_deadline():
    from repro.channel import compute_outcomes

    t, ok = compute_outcomes(jax.random.PRNGKey(0), 2.0, 3.0, 4000)
    t, ok = np.asarray(t), np.asarray(ok)
    assert t.shape == ok.shape == (4000,)
    assert abs(float(t.mean()) - 2.0) < 0.15
    # P(finish) = 1 - exp(-deadline/mean) for Exp(mean)
    want = 1 - math.exp(-3.0 / 2.0)
    assert abs(float(ok.mean()) - want) < 0.02
    np.testing.assert_array_equal(ok, t <= 3.0)


def test_slowest_ok_time_ignores_stragglers():
    import jax.numpy as jnp

    from repro.channel import slowest_ok_time

    t = jnp.array([0.5, 9.0, 1.5])
    ok = jnp.array([True, False, True])
    assert math.isclose(float(slowest_ok_time(t, ok, 4.0)), 1.5)
    # all straggle: the server waits out the whole deadline
    none = jnp.array([False, False, False])
    assert math.isclose(float(slowest_ok_time(t, none, 4.0)), 4.0)


def test_linkplan_straggler_stage_masks_and_extends_latency():
    from repro.channel import LinkPlan

    base = ChannelConfig(num_devices=64, p_up_dbm=40.0)
    strag = ChannelConfig(num_devices=64, p_up_dbm=40.0,
                          compute_mean_s=1.0, deadline_s=1.0)
    kw = dict(n_mod=64, n_labels=10)
    plan0 = LinkPlan.build("fd", base, **kw)
    plan1 = LinkPlan.build("fd", strag, **kw)
    key = jax.random.PRNGKey(7)
    out0 = plan0.draw(key, first_round=False)
    out1 = plan1.draw(key, first_round=False)
    # the channel draw itself is untouched (straggler keys off its own
    # fold of the round key) — link outcomes stay bitwise identical
    np.testing.assert_array_equal(
        np.asarray(out0["t_up"]), np.asarray(out1["t_up"]))
    np.testing.assert_array_equal(out0["dn_ok"], out1["dn_ok"])
    # stragglers are dropped from the aggregation mask like outages
    np.testing.assert_array_equal(out1["up_ok"],
                                  out0["up_ok"] & out1["comp_ok"])
    assert out1["n_straggle"] == int((~out1["comp_ok"]).sum())
    assert 0 < out1["n_straggle"] < 64  # deadline = mean: ~37% straggle
    # latency extends by the slowest finishing device's compute time
    t_comp, comp_ok = out1["t_comp_s"], out1["comp_ok"]
    want = out0["latency_s"] + float(t_comp[comp_ok].max())
    assert math.isclose(out1["latency_s"], want, rel_tol=1e-6)


def test_linkplan_straggler_disabled_is_noop():
    from repro.channel import LinkPlan

    cfg = ChannelConfig(num_devices=8)
    plan = LinkPlan.build("fd", cfg, n_mod=64, n_labels=10)
    assert plan.compute_mean_s == 0.0
    out = plan.draw(jax.random.PRNGKey(3), first_round=True)
    assert "comp_ok" not in out and "n_straggle" not in out


def test_linkplan_all_straggle_waits_full_deadline():
    from repro.channel import LinkPlan

    cfg = ChannelConfig(num_devices=6, p_up_dbm=40.0,
                        compute_mean_s=1.0, deadline_s=1e-9)
    plan = LinkPlan.build("fd", cfg, n_mod=64, n_labels=10)
    out = plan.draw(jax.random.PRNGKey(0), first_round=False)
    assert not out["up_ok"].any()
    assert out["n_straggle"] == 6
    base = LinkPlan.build("fd", ChannelConfig(num_devices=6, p_up_dbm=40.0),
                          n_mod=64, n_labels=10)
    ref = base.draw(jax.random.PRNGKey(0), first_round=False)
    assert math.isclose(out["latency_s"], ref["latency_s"] + 1e-9,
                        rel_tol=1e-6)


def test_downlink_faster_than_uplink_under_asymmetry():
    """P_dn = 40 dBm + full bandwidth: downlink latency for the model
    payload is far below the uplink's for the same payload."""
    cfg = ChannelConfig()
    bits = 32 * 12544
    lat_up, ok_up = simulate_link(jax.random.PRNGKey(3), cfg, bits, True, 500)
    lat_dn, ok_dn = simulate_link(jax.random.PRNGKey(4), cfg, bits, False, 500)
    assert bool(np.all(np.asarray(ok_dn)))
    assert float(np.mean(np.asarray(lat_dn))) < \
        float(np.mean(np.asarray(lat_up)))
