"""Async-overlap vs strict-serial equivalence for the round programs.

A link outcome is a pure function of ``(plan, key)`` and round ``q``'s
key is ``fold_in(fold_in(run_key, q), 3)`` — known from round 1 — so the
double-buffered program (``pipeline_depth > 1``) may dispatch draws
rounds ahead of their collection without changing a single bit.  These
tests lock that contract down for every protocol family, plus the
dispatch-window bookkeeping (stats, plan invalidation under churn, and
the restore path dropping stale handles).
"""
import jax
import numpy as np
import pytest

from repro.channel import ChannelConfig
from repro.core.program import LoopRoundProgram, ProgramOptions
from repro.core.protocols import FederatedConfig, FederatedTrainer
from repro.core.sampling import ChurnConfig
from repro.data import partition_iid, synthetic_images
from repro.launch.service import FederatedService
from repro.models.cnn import CNN

#: history keys that must agree exactly between schedules (compute_s /
#: cum_time_s are host wall-clock measurements and legitimately differ)
_KEYS = ("acc", "loss", "round_latency_s", "uplink_ok", "n_straggle",
         "converged_round")


@pytest.fixture(scope="module")
def data():
    x, y = synthetic_images(jax.random.PRNGKey(42), 900)
    dev_x, dev_y = partition_iid(np.asarray(x[:800]), np.asarray(y[:800]),
                                 4, 200, 10, seed=0)
    return dev_x, dev_y, x[800:], y[800:]


def _fc(protocol):
    return FederatedConfig(protocol=protocol, num_devices=4,
                           local_iters=4, local_batch=16, server_iters=4,
                           server_batch=16, max_rounds=3, n_seed=6,
                           n_inverse=12, seed=0)


def _histories_equal(h1, h2):
    for k in _KEYS:
        if k not in h1:
            assert k not in h2
            continue
        np.testing.assert_array_equal(np.asarray(h1[k]),
                                      np.asarray(h2[k]),
                                      err_msg=f"history[{k!r}]")


@pytest.mark.parametrize("protocol", ["fl", "fd", "mix2fld"])
def test_depth2_bitwise_equals_serial(protocol, data):
    """The double-buffered schedule is bitwise the strict-serial oracle
    on every protocol family (straggler stage on, so the fold_in(key, 7)
    stream is exercised too)."""
    dev_x, dev_y, tx, ty = data
    ch = ChannelConfig(num_devices=4, p_up_dbm=40.0,
                       compute_mean_s=0.05, deadline_s=0.15)
    tr = FederatedTrainer(CNN(), _fc(protocol), ch)
    h1 = tr.run(dev_x, dev_y, tx, ty,
                options=ProgramOptions(pipeline_depth=1))
    h2 = tr.run(dev_x, dev_y, tx, ty,
                options=ProgramOptions(pipeline_depth=2))
    _histories_equal(h1, h2)


def test_default_run_is_depth1(data):
    """run() without options is the strict-serial program — the
    pre-redesign behaviour, bit for bit."""
    dev_x, dev_y, tx, ty = data
    ch = ChannelConfig(num_devices=4, p_up_dbm=40.0)
    tr = FederatedTrainer(CNN(), _fc("fd"), ch)
    h0 = tr.run(dev_x, dev_y, tx, ty)
    assert h0["pipeline"]["pipeline_depth"] == 1
    assert h0["pipeline"]["dispatched"] == h0["pipeline"]["collected"]
    h2 = tr.run(dev_x, dev_y, tx, ty,
                options=ProgramOptions(pipeline_depth=2))
    _histories_equal(h0, h2)


def test_dispatch_window_stats(data):
    """Depth d keeps at most d draws in flight: over R rounds with a
    stable plan, R + (d - 1) dispatches, R collections, d - 1 abandoned
    at finalize."""
    dev_x, dev_y, tx, ty = data
    ch = ChannelConfig(num_devices=4, p_up_dbm=40.0)
    tr = FederatedTrainer(CNN(), _fc("fd"), ch)
    for depth in (1, 2, 3):
        h = tr.run(dev_x, dev_y, tx, ty,
                   options=ProgramOptions(pipeline_depth=depth))
        stats = h["pipeline"]
        R = 3
        assert stats["pipeline_depth"] == depth
        assert stats["dispatched"] == R + (depth - 1)
        assert stats["collected"] == R
        assert stats["abandoned"] == depth - 1


def test_plan_change_invalidates_prefetch(data):
    """A dispatched handle whose plan no longer matches the round's is
    dropped, never collected — the cohort-size-change-under-churn
    safety property, exercised directly through the program."""
    dev_x, dev_y, tx, ty = data
    ch = ChannelConfig(num_devices=4, p_up_dbm=40.0)
    tr = FederatedTrainer(CNN(), _fc("fd"), ch)
    prog = LoopRoundProgram(tr, ProgramOptions(pipeline_depth=2))
    prog.bind(dev_x=dev_x, dev_y=dev_y, test_x=tx, test_y=ty)
    state = tr.init_state()
    state, _ = prog.step(state)          # prefetches round 2's draw
    plan3 = tr.link_plan(state.g_params, n_links=3)
    cohort = state.replace(
        dev_params=jax.tree.map(lambda a: a[:3], state.dev_params),
        dev_gout=state.dev_gout[:3])
    _, rec = prog.step(cohort, {"dev_x": dev_x[:3],
                                     "dev_y": dev_y[:3],
                                     "plan": plan3})
    # round 2's prefetch was drawn under the 4-link plan: must NOT count
    # as collected (it was invalidated and re-drawn serially)
    assert prog.collected == 1
    assert rec["uplink_ok"] <= 3


def test_service_depth2_matches_serial(tmp_path):
    """The continuous-serving driver under churn produces identical
    per-round records at depth 1 and depth 2 (stale prefetches are
    invalidated by the per-round plan), and a depth-2 restore drops the
    pre-restore window."""
    x, y = synthetic_images(jax.random.PRNGKey(7), 700)
    dev_x, dev_y = partition_iid(np.asarray(x[:600]), np.asarray(y[:600]),
                                 4, 150, 10, seed=0)
    fc = FederatedConfig(protocol="fd", num_devices=4, local_iters=2,
                         local_batch=16, server_iters=2, server_batch=16,
                         max_rounds=4, seed=0)
    ch = ChannelConfig(num_devices=4, p_up_dbm=40.0)
    churn = ChurnConfig(p_active=0.75, min_active=2, seed=1)

    def run(depth, ckpt_dir=None):
        svc = FederatedService(
            CNN(), fc, ch, churn=churn, ckpt_dir=ckpt_dir,
            options=ProgramOptions(pipeline_depth=depth))
        svc.bind_data(dev_x, dev_y, x[600:], y[600:])
        recs = svc.run_rounds(4)
        return svc, recs

    _, r1 = run(1)
    svc2, r2 = run(2, ckpt_dir=str(tmp_path))
    for a, b in zip(r1, r2):
        for k in ("round", "acc", "loss", "round_latency_s", "uplink_ok",
                  "n_active"):
            assert np.asarray(a[k] == b[k]).all(), (k, a[k], b[k])

    # restore mid-stream into a fresh depth-2 service: tail identical
    svc3 = FederatedService(CNN(), fc, ch, churn=churn,
                            ckpt_dir=str(tmp_path),
                            options=ProgramOptions(pipeline_depth=2))
    svc3.bind_data(dev_x, dev_y, x[600:], y[600:])
    assert svc3.restore(step=2) == 2
    tail = svc3.run_rounds(2)
    for a, b in zip(r2[2:], tail):
        for k in ("round", "acc", "loss", "uplink_ok"):
            assert np.asarray(a[k] == b[k]).all(), (k, a[k], b[k])


def test_program_options_validation():
    with pytest.raises(ValueError, match="pipeline_depth"):
        ProgramOptions(pipeline_depth=0)
    with pytest.raises(ValueError, match="mesh_shape"):
        ProgramOptions(mesh_shape=(2,))
    with pytest.raises(ValueError, match="mesh_shape"):
        ProgramOptions(mesh_shape=(0, 4))
    assert ProgramOptions(mesh_shape=(2, 4)).mesh_shape == (2, 4)
