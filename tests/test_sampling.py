"""Client sampling: seeded cohorts through both compiled round paths.

Locks down the sampling contract end to end: cohort draws are pure
functions of (seed, round) with sorted/unique/fixed-size invariants,
``sample_ratio=1.0`` reproduces the unsampled paths bit-for-bit (loop
AND compiled grid), sampled sweeps match the per-point loop across
protocols, and the DP ledger composes per-device epsilon over
participation only.  Golden-sized configs (D=4, 8 local iters, 3
rounds) keep the file in the fast tier; the pod-scale acceptance run
(D_pool=10^4) is marked ``slow`` and the sharded 16-device cohort test
``multichip``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import ChannelConfig
from repro.core.privacy import GaussianAccountant
from repro.core.protocols import FederatedConfig, FederatedTrainer
from repro.core.sampling import SamplerConfig, participation_uniforms
from repro.data import partition_iid, synthetic_images
from repro.models.cnn import CNN
from repro.sweep import SweepRunner, make_grid, run_pointwise, run_sweep

CH = ChannelConfig(num_devices=4, p_up_dbm=40.0)


@pytest.fixture(scope="module")
def data():
    x, y = synthetic_images(jax.random.PRNGKey(42), 1400)
    dev_x, dev_y = partition_iid(np.asarray(x[:1200]), np.asarray(y[:1200]),
                                 4, 300, 10, seed=0)
    return dev_x, dev_y, jnp.asarray(x[1200:]), jnp.asarray(y[1200:])


@pytest.fixture(scope="module")
def data16():
    """A 16-device pool (the multichip sampled-cohort test shards its
    8-device cohort across the forced 8-chip host)."""
    x, y = synthetic_images(jax.random.PRNGKey(42), 1000)
    dev_x, dev_y = partition_iid(np.asarray(x[:800]), np.asarray(y[:800]),
                                 16, 50, 10, seed=0)
    return dev_x, dev_y, jnp.asarray(x[800:]), jnp.asarray(y[800:])


def _base(**kw):
    cfg = dict(protocol="mix2fld", num_devices=4, local_iters=8,
               local_batch=16, server_iters=8, server_batch=16,
               max_rounds=3, n_seed=6, n_inverse=12, seed=0)
    cfg.update(kw)
    return FederatedConfig(**cfg)


def _assert_equivalent(result, histories):
    for g, h in enumerate(histories):
        sh = result.history(g)
        np.testing.assert_allclose(sh["acc"], h["acc"], atol=1e-6,
                                   err_msg=f"acc, point {g}")
        np.testing.assert_allclose(sh["loss"], h["loss"], atol=1e-6,
                                   err_msg=f"loss, point {g}")
        np.testing.assert_allclose(sh["round_latency_s"],
                                   h["round_latency_s"], rtol=1e-6,
                                   err_msg=f"latency, point {g}")
        assert sh["uplink_ok"] == h["uplink_ok"], f"uplink_ok, point {g}"
        assert sh["converged_round"] == h["converged_round"], \
            f"converged_round, point {g}"


# ---------------------------------------------------------------------------
# SamplerConfig: draw invariants
# ---------------------------------------------------------------------------

def test_sampler_config_validation():
    with pytest.raises(ValueError, match="sample_ratio"):
        SamplerConfig(sample_ratio=0.0)
    with pytest.raises(ValueError, match="sample_ratio"):
        SamplerConfig(sample_ratio=1.5)
    with pytest.raises(ValueError, match="min_active"):
        SamplerConfig(min_active=0)
    with pytest.raises(ValueError, match="sample_ratio"):
        FederatedConfig(sample_ratio=-0.5)


def test_cohort_size_is_ceil_with_floor_and_cap():
    assert SamplerConfig(sample_ratio=0.5).cohort_size(10) == 5
    assert SamplerConfig(sample_ratio=0.3).cohort_size(10) == 3  # not 4:
    # 0.3 * 10 is 3.0000000000000004 in binary floats
    assert SamplerConfig(sample_ratio=0.25).cohort_size(10) == 3  # ceil
    assert SamplerConfig(sample_ratio=1.0).cohort_size(10) == 10
    assert SamplerConfig(sample_ratio=0.01).cohort_size(10) == 1
    assert SamplerConfig(sample_ratio=0.01, min_active=3).cohort_size(10) \
        == 3
    assert SamplerConfig(sample_ratio=0.5, min_active=99).cohort_size(4) \
        == 4  # min_active clamps to the pool


@pytest.mark.parametrize("ratio", [0.05, 0.3, 0.5, 0.9, 1.0])
@pytest.mark.parametrize("pool", [1, 2, 7, 16, 101])
def test_cohort_invariants(ratio, pool):
    """Deterministic, sorted, duplicate-free, exactly cohort_size
    entries in range, and >= min_active."""
    s = SamplerConfig(sample_ratio=ratio, min_active=2, seed=5)
    for p in (1, 2, 9):
        c = s.cohort(fed_seed=3, round_=p, pool_size=pool)
        c2 = s.cohort(fed_seed=3, round_=p, pool_size=pool)
        assert np.array_equal(c, c2)
        assert len(c) == s.cohort_size(pool) >= min(2, pool)
        assert len(np.unique(c)) == len(c)
        assert np.all(np.diff(c) > 0)
        assert c.min() >= 0 and c.max() < pool


def test_cohorts_nest_across_ratios():
    """Smallest-uniform selection: the 30% cohort is a subset of the 60%
    cohort of the same round/seed."""
    lo = SamplerConfig(sample_ratio=0.3, seed=1)
    hi = SamplerConfig(sample_ratio=0.6, seed=1)
    for p in (1, 2, 3):
        a = set(lo.cohort(0, p, 40).tolist())
        b = set(hi.cohort(0, p, 40).tolist())
        assert a < b


def test_full_ratio_cohort_is_arange_but_consumes_stream():
    """sample_ratio=1 must return the whole pool in order, drawing the
    same uniforms a fractional ratio would (stream stability)."""
    s = SamplerConfig(sample_ratio=1.0, seed=2)
    assert np.array_equal(s.cohort(0, 1, 6), np.arange(6))
    u1, _ = participation_uniforms(0, 2, 1, 6)
    u2, _ = participation_uniforms(0, 2, 1, 6)
    assert np.array_equal(u1, u2)


def test_participation_streams_are_disjoint_per_mechanism():
    """Identical (fed_seed, seed, round) must still give churn and the
    sampler independent uniforms — the mechanism tag separates the
    streams, so composing churn with sampling never re-reads values the
    other mechanism conditioned on."""
    from repro.core.sampling import MECH_CHURN, MECH_SAMPLE

    u_s, _ = participation_uniforms(0, 0, 1, 64, mechanism=MECH_SAMPLE)
    u_c, _ = participation_uniforms(0, 0, 1, 64, mechanism=MECH_CHURN)
    assert not np.array_equal(u_s, u_c)
    # the default is the sampler stream
    u_d, _ = participation_uniforms(0, 0, 1, 64)
    assert np.array_equal(u_d, u_s)


def test_participation_counts_match_cohorts():
    s = SamplerConfig(sample_ratio=0.5, seed=0)
    counts = s.participation_counts(0, 6, 4)
    want = np.zeros(4, np.int64)
    for p in range(1, 7):
        want[s.cohort(0, p, 4)] += 1
    assert np.array_equal(counts, want)
    assert counts.sum() == 6 * s.cohort_size(4)


def test_cohort_invariants_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=60, deadline=None)
    @given(ratio=st.floats(0.01, 1.0, allow_nan=False),
           pool=st.integers(1, 64), min_active=st.integers(1, 8),
           fed_seed=st.integers(0, 5), round_=st.integers(1, 20))
    def check(ratio, pool, min_active, fed_seed, round_):
        s = SamplerConfig(sample_ratio=ratio, min_active=min_active,
                          seed=7)
        c = s.cohort(fed_seed, round_, pool)
        assert np.array_equal(c, s.cohort(fed_seed, round_, pool))
        assert len(c) == s.cohort_size(pool)
        assert len(c) >= min(min_active, pool)
        assert len(np.unique(c)) == len(c)
        assert np.all(np.diff(c) > 0)
        assert (c >= 0).all() and (c < pool).all()

    check()


# ---------------------------------------------------------------------------
# sample_ratio=1.0 bit-identity on BOTH round paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("protocol", ["fl", "mix2fld"])
def test_ratio_one_loop_is_bit_identical_to_unsampled(data, protocol):
    """A non-default sample_seed at ratio 1.0 must leave loop-path
    histories bitwise unchanged (the sampler consumes its own stream,
    nothing the round draws from)."""
    dev_x, dev_y, tx, ty = data
    h0 = FederatedTrainer(CNN(), _base(protocol=protocol), CH).run(
        dev_x, dev_y, tx, ty)
    h1 = FederatedTrainer(
        CNN(), _base(protocol=protocol, sample_ratio=1.0, sample_seed=123),
        CH).run(dev_x, dev_y, tx, ty)
    assert h0["acc"] == h1["acc"]
    assert h0["loss"] == h1["loss"]
    assert h0["uplink_ok"] == h1["uplink_ok"]
    assert h0["converged_round"] == h1["converged_round"]


def test_ratio_one_sweep_is_bit_identical_to_unsampled(data):
    """Grid path: ratio-1.0 points land in the unsampled program group
    (same structural key) and reproduce its arrays exactly."""
    dev_x, dev_y, tx, ty = data
    g0 = make_grid(_base(), CH, eta=(0.01, 0.02))
    g1 = make_grid(_base(sample_ratio=1.0, sample_seed=123), CH,
                   eta=(0.01, 0.02))
    assert list(g0.program_groups()) == list(g1.program_groups()) \
        == [("mix2fld", "identity", 4, "cnn", "digits")]
    r0 = run_sweep(CNN(), g0, dev_x, dev_y, tx, ty)
    r1 = run_sweep(CNN(), g1, dev_x, dev_y, tx, ty)
    assert np.array_equal(r0.acc, r1.acc)
    assert np.array_equal(r0.loss, r1.loss)
    assert np.array_equal(r0.up_ok, r1.up_ok)
    assert np.array_equal(r0.converged, r1.converged)


# ---------------------------------------------------------------------------
# Sampled sweep-vs-loop equivalence
# ---------------------------------------------------------------------------

def test_sampled_sweep_matches_loop_across_protocols(data):
    """The headline equivalence: sample_ratio in {1.0, 0.5} crossed with
    fl/fd/mix2fld — six programs (cohort size is structural), every
    point's history equal to the per-point loop."""
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(), CH, protocol=("fl", "fd", "mix2fld"),
                     sample_ratio=(1.0, 0.5))
    runner = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty)
    assert runner.programs == 6
    res = runner.run()
    _assert_equivalent(res, run_pointwise(CNN(), grid, dev_x, dev_y,
                                          tx, ty))


def test_sample_seed_axis_batches_in_one_program(data):
    """Different cohort draws at one ratio share a program (the seed is
    host-absorbed into the gather indices) and still match the loop."""
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(protocol="fd", sample_ratio=0.5), CH,
                     sample_seed=(0, 7))
    runner = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty)
    assert runner.programs == 1
    assert list(grid.program_groups()) == [("fd", "identity", 2, "cnn", "digits")]
    res = runner.run()
    _assert_equivalent(res, run_pointwise(CNN(), grid, dev_x, dev_y,
                                          tx, ty))
    # distinct seeds draw distinct cohorts -> distinct trajectories
    assert not np.array_equal(res.acc[0], res.acc[1]) or \
        not np.array_equal(res.loss[0], res.loss[1])


def test_sampled_round_once_scatters_back_to_pool(data):
    """Non-participants keep their device state bit-for-bit; cohort rows
    change.  Also covers the plan-rebuild guard (a pool-sized plan is
    resized to the cohort)."""
    dev_x, dev_y, tx, ty = data
    fc = _base(protocol="fd", sample_ratio=0.5)
    tr = FederatedTrainer(CNN(), fc, CH)
    state = tr.init_state()
    pool_plan = tr.link_plan(state["g_params"], n_links=4)
    before = jax.tree.map(np.asarray, state["dev_params"])
    new_state, rec = tr.round_once(state, dev_x, dev_y, tx, ty,
                                   plan=pool_plan)
    cohort = rec["cohort"]
    assert rec["n_active"] == 2 and len(cohort) == 2
    assert np.array_equal(cohort, fc.sampler().cohort(fc.seed, 1, 4))
    rest = np.setdiff1d(np.arange(4), cohort)
    for leaf_b, leaf_a in zip(jax.tree.leaves(before),
                              jax.tree.leaves(new_state["dev_params"])):
        assert np.array_equal(leaf_b[rest], np.asarray(leaf_a)[rest])
    changed = any(
        not np.array_equal(leaf_b[cohort], np.asarray(leaf_a)[cohort])
        for leaf_b, leaf_a in zip(
            jax.tree.leaves(before),
            jax.tree.leaves(new_state["dev_params"])))
    assert changed


# ---------------------------------------------------------------------------
# Participation-correct DP accounting
# ---------------------------------------------------------------------------

def test_accountant_composes_per_device_participation_only():
    """The core satellite bugfix as a unit test: three rounds with
    2-of-3 cohorts — per-device epsilon composes over 2 rounds, not 3."""
    acct = GaussianAccountant(sigma=1.0, delta=1e-5, sample_ratio=2 / 3)
    acct.step(cohort=[0, 1]).step(cohort=[1, 2]).step(cohort=[0, 2])
    assert acct.rounds == 3
    assert acct.device_rounds == {0: 2, 1: 2, 2: 2}
    assert acct.device_rounds_max() == 2
    assert acct.epsilon_device_max() == pytest.approx(acct.epsilon(2))
    assert acct.epsilon_device_max() < acct.epsilon()
    led = acct.ledger()
    assert led["participating_devices"] == 3
    assert led["device_rounds_max"] == 2
    assert led["epsilon_device_max"] == pytest.approx(acct.epsilon(2))
    assert led["sample_ratio"] == pytest.approx(2 / 3)


def test_accountant_without_cohorts_stays_conservative():
    acct = GaussianAccountant(sigma=1.0, delta=1e-5)
    acct.step().step()
    assert acct.device_rounds_max() == 2
    assert acct.epsilon_device_max() == pytest.approx(acct.epsilon())
    assert acct.ledger()["participating_devices"] is None


def test_sampled_dp_ledger_matches_loop_and_reflects_participation(data):
    """dp_gaussian at sample_ratio=0.5 over 6 rounds: the sweep's
    history["dp"] equals the loop path's ledger exactly, and per-device
    epsilon < all-rounds epsilon (sample_seed=0 draws a max
    participation of 5/6 rounds for this config — regression for the
    every-device-every-round over-report)."""
    dev_x, dev_y, tx, ty = data
    fc = _base(protocol="fd", codec="dp_gaussian", dp_sigma=2.0,
               sample_ratio=0.5, max_rounds=6)
    grid = make_grid(fc, CH, eta=(0.01,))
    res = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty).run()
    (h,) = run_pointwise(CNN(), grid, dev_x, dev_y, tx, ty)
    led = res.history(0)["dp"]
    assert led == h["dp"]
    assert led["sample_ratio"] == 0.5
    assert led["device_rounds_max"] == 5
    assert led["epsilon_device_max"] < led["epsilon"]
    counts = fc.sampler().participation_counts(fc.seed, 6, 4)
    assert led["participating_devices"] == int((counts > 0).sum())
    row = res.frames()[0]
    assert row["dp_epsilon_device_max"] == \
        pytest.approx(led["epsilon_device_max"])


# ---------------------------------------------------------------------------
# Sharded sampled cohorts (the pod-scale path)
# ---------------------------------------------------------------------------

def _base16(**kw):
    cfg = dict(protocol="mix2fld", num_devices=16, local_iters=4,
               local_batch=8, server_iters=4, server_batch=8,
               max_rounds=3, n_seed=4, n_inverse=8, seed=0)
    cfg.update(kw)
    return FederatedConfig(**cfg)


CH16 = ChannelConfig(num_devices=16, p_up_dbm=40.0)


@pytest.mark.multichip
def test_sampled_sharded_sweep_multichip(data16):
    """16-device pool, ratio 0.5: the 8-device cohort must shard across
    the multichip mesh and reproduce the vmapped sampled sweep."""
    dev_x, dev_y, tx, ty = data16
    grid_s = make_grid(_base16(sample_ratio=0.5, shard_devices=True),
                       CH16, eta=(0.01, 0.02))
    runner = SweepRunner(CNN(), grid_s, dev_x, dev_y, tx, ty)
    res_s = runner.run()
    grid_v = make_grid(_base16(sample_ratio=0.5), CH16, eta=(0.01, 0.02))
    res_v = SweepRunner(CNN(), grid_v, dev_x, dev_y, tx, ty).run()
    np.testing.assert_allclose(res_s.acc, res_v.acc, atol=1e-4)
    np.testing.assert_allclose(res_s.loss, res_v.loss, atol=1e-4)
    assert np.array_equal(res_s.up_ok, res_v.up_ok)


@pytest.mark.multichip
def test_sampled_sharded_trainer_multichip(data16):
    """Loop path under sharding: the trainer's mesh spans the cohort
    (8 devices), more than one chip carries it, and histories match the
    vmapped trainer."""
    tr = FederatedTrainer(CNN(), _base16(sample_ratio=0.5,
                                         shard_devices=True), CH16)
    assert tr.mesh.devices.size > 1
    assert tr.mesh.shape["data"] <= 8  # cohort-sized, not pool-sized
    dev_x, dev_y, tx, ty = data16
    h_s = tr.run(dev_x, dev_y, tx, ty)
    h_v = FederatedTrainer(CNN(), _base16(sample_ratio=0.5), CH16).run(
        dev_x, dev_y, tx, ty)
    np.testing.assert_allclose(h_s["acc"], h_v["acc"], atol=1e-4)
    np.testing.assert_allclose(h_s["loss"], h_v["loss"], atol=1e-4)


# ---------------------------------------------------------------------------
# Pod-scale acceptance: D_pool = 10^4 through the SweepRunner
# ---------------------------------------------------------------------------

class _TinyNet:
    """~500-parameter linear probe over 4x4-pooled images — small enough
    that a 10^4-device pool's stacked parameters fit comfortably."""

    def init(self, key):
        k, _ = jax.random.split(key)
        return {"w": jax.random.normal(k, (49, 10)) * 0.1,
                "b": jnp.zeros((10,))}

    def apply(self, params, x):
        b = x.shape[0]
        pooled = x[..., 0].reshape(b, 7, 4, 7, 4).mean(axis=(2, 4))
        return pooled.reshape(b, 49) @ params["w"] + params["b"]


@pytest.mark.slow
def test_pool_scale_sampled_sweep_10k_devices():
    """Acceptance: a sample_ratio=0.5 sweep at D_pool=10^4 runs through
    SweepRunner, matches the loop path, and carries a participation-only
    DP ledger."""
    D, n_loc = 10_000, 10  # partition_iid needs >= 1 sample per class
    x, y = synthetic_images(jax.random.PRNGKey(42), D * n_loc + 200)
    dev_x, dev_y = partition_iid(np.asarray(x[:D * n_loc]),
                                 np.asarray(y[:D * n_loc]), D, n_loc, 10,
                                 seed=0)
    tx, ty = jnp.asarray(x[D * n_loc:]), jnp.asarray(y[D * n_loc:])
    fc = FederatedConfig(protocol="fd", num_devices=D, local_iters=1,
                         local_batch=4, server_iters=1, server_batch=4,
                         max_rounds=2, codec="dp_gaussian", dp_sigma=2.0,
                         sample_ratio=0.5, seed=0)
    ch = ChannelConfig(num_devices=D, p_up_dbm=40.0)
    grid = make_grid(fc, ch, eta=(0.01,))
    assert list(grid.program_groups()) == [("fd", "dp_gaussian", 5000, "cnn", "digits")]
    res = SweepRunner(_TinyNet(), grid, dev_x, dev_y, tx, ty).run()
    (h,) = run_pointwise(_TinyNet(), grid, dev_x, dev_y, tx, ty)
    _assert_equivalent(res, [h])
    led = res.history(0)["dp"]
    assert led == h["dp"]
    assert led["sample_ratio"] == 0.5
    # participation-only composition: the accountant's per-device counts
    # are exactly the sampler's, not rounds-for-everyone
    counts = fc.sampler().participation_counts(fc.seed, 2, D)
    assert led["participating_devices"] == int((counts > 0).sum()) < D
    assert led["device_rounds_max"] == int(counts.max())
    assert led["epsilon_device_max"] <= led["epsilon"]
