"""End-to-end behaviour tests for the paper's system: the full Mix2FLD
pipeline (Algorithm 1) against its baselines under the paper's asymmetric
channel, plus the optimizer/data substrates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.channel import ChannelConfig
from repro.core.protocols import FederatedConfig, FederatedTrainer
from repro.data import partition_noniid, synthetic_images, synthetic_tokens
from repro.models.cnn import CNN


@pytest.mark.slow
def test_mix2fld_full_pipeline_asymmetric_noniid():
    """Algorithm 1 end to end, the paper's headline setting: asymmetric
    channel + non-IID data.  Mix2FLD must (a) run every stage, (b) keep
    uploading despite the uplink that kills FL, (c) learn."""
    key = jax.random.PRNGKey(0)
    x, y = synthetic_images(key, 6000)
    dev_x, dev_y = partition_noniid(x[:5000], y[:5000], 10)
    tx, ty = jnp.asarray(x[5000:]), jnp.asarray(y[5000:])
    asym = ChannelConfig(num_devices=10)  # paper defaults: 23 vs 40 dBm
    fc = FederatedConfig(protocol="mix2fld", num_devices=10, local_iters=80,
                         local_batch=32, server_iters=80, max_rounds=4)
    h = FederatedTrainer(CNN(), fc, asym).run(dev_x, dev_y, tx, ty)
    assert all(n > 0 for n in h["uplink_ok"])  # FD uplink survives
    assert h["acc"][-1] > 0.25

    # FL under the same channel never gets a model through (Sec. IV)
    fc_fl = FederatedConfig(protocol="fl", num_devices=10, local_iters=80,
                            local_batch=32, max_rounds=2)
    h_fl = FederatedTrainer(CNN(), fc_fl, asym).run(dev_x, dev_y, tx, ty)
    assert all(n == 0 for n in h_fl["uplink_ok"])


def test_optimizers_decrease_quadratic():
    def loss(p):
        return jnp.sum((p["w"] - 3.0) ** 2)

    for name in ("sgd", "momentum", "adam"):
        opt = optim.get_optimizer(name, 0.1)
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        l0 = float(loss(params))
        for _ in range(50):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params)
        assert float(loss(params)) < l0 * 0.05, name


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 100.0}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)
    assert float(norm) == pytest.approx(200.0)


def test_synthetic_images_learnable_and_stable():
    x, y = synthetic_images(jax.random.PRNGKey(0), 2000)
    assert x.shape == (2000, 28, 28, 1)
    assert float(x.min()) >= 0.0 and float(x.max()) <= 1.0
    assert np.bincount(np.asarray(y), minlength=10).min() > 100
    # same key -> same data (fixed seed reproducibility)
    x2, y2 = synthetic_images(jax.random.PRNGKey(0), 2000)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(x2))


def test_synthetic_tokens_in_range():
    toks = synthetic_tokens(jax.random.PRNGKey(1), 4, 128, 997)
    assert toks.shape == (4, 128)
    assert int(toks.min()) >= 0 and int(toks.max()) < 997


def test_cosine_schedule_monotone_after_warmup():
    lr = optim.cosine_schedule(1.0, warmup=10, total=100)
    vals = [float(lr(s)) for s in range(0, 100, 10)]
    assert vals[0] == 0.0
    assert max(vals) <= 1.0
    assert vals[-1] < vals[2]
