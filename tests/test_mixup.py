"""Mixup / inverse-Mixup (Prop. 1) tests — no external deps.

Parametrized equivalents of the hypothesis property tests live here so the
properties stay covered when ``hypothesis`` is absent; the randomized
versions are in ``test_mixup_properties.py`` (skipped without hypothesis).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mixup import (circulant, cycle_lams, find_label_cycles,
                              inverse_mixup, inverse_mixup_cycles,
                              inverse_mixup_n, inverse_mixup_ratios,
                              make_mixup_batch, mixup_pairs, pair_symmetric)
from repro.core.privacy import sample_privacy
from repro.kernels.mixup_kernel import mixup_pallas

LAM_GRID = [0.05, 0.1, 0.2, 0.3, 0.45]


# ---------------------------------------------------------------------------
# Proposition 1 (parametrized stand-ins for the hypothesis properties)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 3, 4, 5])
@pytest.mark.parametrize("spread", [0.5, 1.0, 2.0])
def test_prop1_inverse_is_matrix_inverse(n, spread):
    lams = np.linspace(1.0, 1.0 + spread, n)
    lams /= lams.sum()
    C = circulant(jnp.asarray(lams, jnp.float32))
    R = inverse_mixup_ratios(jnp.asarray(lams, jnp.float32))
    np.testing.assert_allclose(np.asarray(R @ C), np.eye(n), atol=1e-3)


@pytest.mark.parametrize("lam", LAM_GRID)
def test_inverse_mixup_recovers_hard_labels(lam):
    a = jnp.array([1.0, 0.0])
    b = jnp.array([0.0, 1.0])
    mixed_a = lam * a + (1 - lam) * b
    mixed_b = lam * b + (1 - lam) * a
    s1, s2 = inverse_mixup(mixed_a, mixed_b, lam)
    np.testing.assert_allclose(np.asarray(s1), [1.0, 0.0], atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), [0.0, 1.0], atol=1e-4)


@pytest.mark.parametrize("lam,seed", [(0.05, 0), (0.2, 1), (0.45, 2)])
def test_inverse_mixup_on_samples_not_equal_raw(lam, seed):
    """Inversely mixed samples recover the LABEL but (for cross-device
    pairs with different raw content) not the raw SAMPLE."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    xa1, xa2 = jax.random.normal(k1, (8,)), jax.random.normal(k2, (8,))
    xb1, xb2 = jax.random.normal(k3, (8,)), jax.random.normal(k4, (8,))
    ma = lam * xa1 + (1 - lam) * xa2
    mb = lam * xb1 + (1 - lam) * xb2
    s1, s2 = inverse_mixup(ma, mb, lam)
    for s in (s1, s2):
        for raw in (xa1, xa2, xb1, xb2):
            assert float(jnp.linalg.norm(s - raw)) > 1e-4


@pytest.mark.parametrize("n,seed", [(3, 0), (4, 7), (6, 42)])
def test_inverse_mixup_n_unmixes_cyclic_stack(n, seed):
    lams = np.linspace(1, 2, n)
    lams /= lams.sum()
    key = jax.random.PRNGKey(seed)
    raw = jax.random.normal(key, (n, 5))
    C = np.asarray(circulant(jnp.asarray(lams, jnp.float32)))
    mixed = jnp.asarray(C) @ raw
    rec = inverse_mixup_n(mixed, jnp.asarray(lams, jnp.float32))
    np.testing.assert_allclose(np.asarray(rec), np.asarray(raw), atol=1e-2)


# ---------------------------------------------------------------------------
# Device-side Mixup
# ---------------------------------------------------------------------------

def test_mixup_pairs_have_different_labels():
    key = jax.random.PRNGKey(0)
    labels = jax.random.randint(key, (200,), 0, 10)
    i, j = mixup_pairs(key, labels, 64, 10)
    assert bool(jnp.all(labels[i] != labels[j]))


def test_make_mixup_batch_soft_labels_sum_to_one():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (50, 4))
    y = jax.random.randint(key, (50,), 0, 10)
    i, j = mixup_pairs(key, y, 20, 10)
    mixed, soft, (mi, ma) = make_mixup_batch(x, y, i, j, 0.3, 10)
    np.testing.assert_allclose(np.asarray(jnp.sum(soft, -1)), 1.0, atol=1e-5)
    assert mixed.shape == (20, 4)


def test_vmapped_mixup_matches_per_device():
    """The batched (D, n_seed) path equals the per-device loop exactly."""
    key = jax.random.PRNGKey(3)
    D, n, C = 4, 30, 10
    dev_x = jax.random.normal(key, (D, n, 6))
    dev_y = jax.random.randint(jax.random.fold_in(key, 1), (D, n), 0, C)
    keys = jax.random.split(jax.random.fold_in(key, 2), D)
    bi, bj = jax.vmap(mixup_pairs, in_axes=(0, 0, None, None))(
        keys, dev_y, 8, C)
    bm, bs, (bmi, bma) = jax.vmap(
        make_mixup_batch, in_axes=(0, 0, 0, 0, None, None))(
        dev_x, dev_y, bi, bj, 0.2, C)
    for d in range(D):
        li, lj = mixup_pairs(keys[d], dev_y[d], 8, C)
        lm, ls, (lmi, lma) = make_mixup_batch(dev_x[d], dev_y[d], li, lj,
                                              0.2, C)
        np.testing.assert_array_equal(np.asarray(bi[d]), np.asarray(li))
        np.testing.assert_allclose(np.asarray(bm[d]), np.asarray(lm),
                                   atol=1e-6)
        np.testing.assert_array_equal(np.asarray(bmi[d]), np.asarray(lmi))


# ---------------------------------------------------------------------------
# Server-side pairing (vectorized sort-based matcher)
# ---------------------------------------------------------------------------

def test_pair_symmetric_matches_reversed_pairs_across_devices():
    minor = np.array([0, 1, 2, 1, 0])
    major = np.array([1, 0, 3, 0, 1])
    dev = np.array([0, 1, 0, 0, 0])
    pairs = pair_symmetric(minor, major, dev)
    assert len(pairs) >= 1
    for i, j in pairs:
        assert minor[i] == major[j] and major[i] == minor[j]
        assert dev[i] != dev[j]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pair_symmetric_invariants_at_scale(seed):
    """Symmetric labels, no same-device pairs, no index reuse — on a
    (D*Ns,) upload set the size the trainer actually produces."""
    rng = np.random.default_rng(seed)
    n, C, D = 500, 10, 50
    minor = rng.integers(0, C, n)
    major = (minor + rng.integers(1, C, n)) % C
    dev = rng.integers(0, D, n)
    pairs = pair_symmetric(minor, major, dev)
    assert len(pairs) > 0
    assert np.all(minor[pairs[:, 0]] == major[pairs[:, 1]])
    assert np.all(major[pairs[:, 0]] == minor[pairs[:, 1]])
    assert np.all(dev[pairs[:, 0]] != dev[pairs[:, 1]])
    flat = pairs.reshape(-1)
    assert len(set(flat.tolist())) == flat.size  # each upload used once


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_pair_symmetric_is_maximal(seed):
    """After the repair pass no matchable (forward, reverse) pair may be
    left over: the matching is maximal like the greedy reference."""
    rng = np.random.default_rng(seed)
    n, C, D = 60, 4, 3
    minor = rng.integers(0, C, n)
    major = (minor + rng.integers(1, C, n)) % C
    dev = rng.integers(0, D, n)
    pairs = pair_symmetric(minor, major, dev)
    used = set(pairs.reshape(-1).tolist())
    free = [k for k in range(n) if k not in used and minor[k] != major[k]]
    for a in free:
        for b in free:
            matchable = (minor[a] == major[b] and major[a] == minor[b]
                         and dev[a] != dev[b] and a != b)
            assert not matchable, (a, b)


def test_find_label_cycles_bounded_on_open_chains():
    """A label graph whose chains never close is the DFS worst case; the
    step budget must bound it instead of hanging."""
    import time
    rng = np.random.default_rng(0)
    n = 500
    minor = rng.integers(0, 9, n)
    major = minor + 1  # ladder: no cycle can ever close
    dev = rng.integers(0, 50, n)
    t0 = time.perf_counter()
    cycles = find_label_cycles(minor, major, dev, 6)
    assert time.perf_counter() - t0 < 60
    assert len(cycles) == 0


def _greedy_pairs_oracle(minor, major, dev):
    """Plain greedy matcher: forwards in index order, first unused
    cross-device symmetric reverse in index order.  O(n^2) reference for
    the maximality contract of the sort-based matcher."""
    n = minor.shape[0]
    used = np.zeros(n, bool)
    out = []
    for a in range(n):
        if used[a] or minor[a] >= major[a]:
            continue
        for b in range(n):
            if (not used[b] and minor[b] > major[b]
                    and minor[a] == major[b] and major[a] == minor[b]
                    and dev[a] != dev[b]):
                used[a] = used[b] = True
                out.append((a, b))
                break
    return out


def test_pair_symmetric_duplicate_keys_rank_misalignment():
    """Adversarial tie case: one unordered key (0, 1) with device orders
    chosen so the bulk rank alignment hits a same-device pair mid-group;
    the greedy repair pass must recover what is recoverable and the yield
    must match the plain greedy oracle."""
    # forwards (0 -> 1) on devices [0, 1, 2]; reverses (1 -> 0) on
    # devices [2, 1, 0]: device-ascending vs device-descending sorting
    # aligns rank 1 to the same device (1 vs 1) and drops it
    minor = np.array([0, 0, 0, 1, 1, 1])
    major = np.array([1, 1, 1, 0, 0, 0])
    dev = np.array([0, 1, 2, 2, 1, 0])
    pairs = pair_symmetric(minor, major, dev)
    assert np.all(minor[pairs[:, 0]] == major[pairs[:, 1]])
    assert np.all(dev[pairs[:, 0]] != dev[pairs[:, 1]])
    assert len(pairs) >= len(_greedy_pairs_oracle(minor, major, dev))
    assert len(pairs) == 3  # the full matching exists and must be found


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_pair_symmetric_same_device_heavy_uses_repair(seed):
    """Two devices with heavily skewed upload counts force many
    same-device bulk alignments — the greedy-repair path must still
    deliver at least the plain greedy oracle's yield."""
    rng = np.random.default_rng(seed)
    n, C = 120, 4
    minor = rng.integers(0, C, n)
    major = (minor + rng.integers(1, C, n)) % C
    # 90% of uploads on device 0, the rest on device 1
    dev = np.where(rng.random(n) < 0.9, 0, 1)
    pairs = pair_symmetric(minor, major, dev)
    assert np.all(minor[pairs[:, 0]] == major[pairs[:, 1]])
    assert np.all(major[pairs[:, 0]] == minor[pairs[:, 1]])
    assert np.all(dev[pairs[:, 0]] != dev[pairs[:, 1]])
    flat = pairs.reshape(-1)
    assert len(set(flat.tolist())) == flat.size
    assert len(pairs) >= len(_greedy_pairs_oracle(minor, major, dev))


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
def test_pair_symmetric_yield_matches_greedy_oracle(seed):
    """Maximality contract at the trainer's upload-set scale: the
    sort-based matcher never yields fewer pairs than the plain greedy
    matcher it replaced."""
    rng = np.random.default_rng(seed)
    n, C, D = 300, 6, 8
    minor = rng.integers(0, C, n)
    major = (minor + rng.integers(1, C, n)) % C
    dev = rng.integers(0, D, n)
    pairs = pair_symmetric(minor, major, dev)
    assert len(pairs) >= len(_greedy_pairs_oracle(minor, major, dev))


def test_pair_symmetric_empty_and_degenerate():
    empty = pair_symmetric(np.array([]), np.array([]), np.array([]))
    assert empty.shape == (0, 2)
    # all-forward orientation: nothing to match
    none = pair_symmetric(np.array([0, 0]), np.array([1, 1]),
                          np.array([0, 1]))
    assert len(none) == 0


# ---------------------------------------------------------------------------
# Batched inverse-Mixup vs the scalar oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lam", [0.1, 0.3])
def test_batched_inverse_mixup_matches_scalar_oracle(lam):
    """The kernel route (mixup_pallas with lam_hat ratios) equals the
    scalar ``inverse_mixup`` reference within fp32 tolerance."""
    rng = np.random.default_rng(4)
    n, C, D = 200, 10, 20
    minor = rng.integers(0, C, n)
    major = (minor + rng.integers(1, C, n)) % C
    dev = rng.integers(0, D, n)
    mixed = jnp.asarray(rng.normal(size=(n, 49)), jnp.float32)
    pairs = pair_symmetric(minor, major, dev)
    assert len(pairs) > 5
    lam_hat = lam / (2.0 * lam - 1.0)
    la = jnp.full((len(pairs),), lam_hat, jnp.float32)
    a, b = mixed[pairs[:, 0]], mixed[pairs[:, 1]]
    s1 = mixup_pallas(a, b, la, 1.0 - la)
    s2 = mixup_pallas(b, a, la, 1.0 - la)
    for k in range(len(pairs)):
        o1, o2 = inverse_mixup(mixed[pairs[k, 0]], mixed[pairs[k, 1]], lam)
        np.testing.assert_allclose(np.asarray(s1[k]), np.asarray(o1),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(s2[k]), np.asarray(o2),
                                   atol=1e-5)


def test_inverse_mixup_cycles_pair_case_equals_inverse_mixup():
    """A 2-cycle through the general-N path is exactly the N=2 formula."""
    rng = np.random.default_rng(5)
    mixed = jnp.asarray(rng.normal(size=(2, 16)), jnp.float32)
    lam = 0.25
    out = inverse_mixup_cycles(mixed, np.array([[0, 1]]), lam)
    s1, s2 = inverse_mixup(mixed[0], mixed[1], lam)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(s1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(s2), atol=1e-5)


@pytest.mark.parametrize("length", [3, 4, 5])
def test_inverse_mixup_cycles_unmixes_constructed_cycle(length):
    """m_k = lam x_k + (1-lam) x_{k+1} over a label cycle is exactly
    inverted by the cyclic lam-order ratios (Prop. 1, general N)."""
    rng = np.random.default_rng(length)
    lam = 0.2
    raw = rng.normal(size=(length, 12)).astype(np.float32)
    m = np.stack([lam * raw[k] + (1 - lam) * raw[(k + 1) % length]
                  for k in range(length)])
    minor = np.arange(length)
    major = (minor + 1) % length
    dev = np.arange(length)
    cycles = find_label_cycles(minor, major, dev, length)
    assert cycles.shape == (1, length)
    out = inverse_mixup_cycles(jnp.asarray(m), cycles, lam)
    want = raw[cycles.reshape(-1)]
    np.testing.assert_allclose(np.asarray(out), want, atol=1e-4)


def test_find_label_cycles_invariants():
    rng = np.random.default_rng(9)
    n, C, D = 300, 10, 30
    minor = rng.integers(0, C, n)
    major = (minor + rng.integers(1, C, n)) % C
    dev = rng.integers(0, D, n)
    cycles = find_label_cycles(minor, major, dev, 3)
    assert len(cycles) > 0
    flat = cycles.reshape(-1)
    assert len(set(flat.tolist())) == flat.size  # disjoint within a call
    for row in cycles:
        for k in range(3):
            nxt = row[(k + 1) % 3]
            assert major[row[k]] == minor[nxt]      # label chain closes
            assert dev[row[k]] != dev[nxt]          # adjacent devices differ


def test_cycle_lams_matrix_is_invertible_off_half():
    for n in (2, 3, 5, 7):
        C = np.asarray(circulant(cycle_lams(n, 0.2)))
        assert np.isfinite(np.linalg.cond(C)) and np.linalg.cond(C) < 1e3


# ---------------------------------------------------------------------------
# Cycle-search edge cases: single-class uploads, lam = 0.5 singularities,
# and DFS step-budget exhaustion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("length", [2, 3, 4])
def test_find_label_cycles_single_class_uploads_is_empty(length):
    """Single-class uploads have minor == major everywhere: no edge of the
    label multigraph is usable, so every cycle length returns empty."""
    minor = np.full(50, 3)
    major = np.full(50, 3)
    dev = np.arange(50) % 5
    cycles = find_label_cycles(minor, major, dev, length)
    assert cycles.shape == (0, length)


def test_pair_symmetric_single_class_uploads_is_empty():
    same = np.full(20, 7)
    assert len(pair_symmetric(same, same, np.arange(20) % 4)) == 0


def test_collect_seeds_single_class_degrades_to_soft_labels():
    """A population that only holds one class cannot pair or cycle; the
    mix2fld pipeline must fall back to soft-label training, not crash."""
    from repro.core.protocols import FederatedConfig, collect_seeds
    key = jax.random.PRNGKey(0)
    dev_x = jax.random.normal(key, (4, 40, 28, 28, 1))
    dev_y = jnp.full((4, 40), 2, jnp.int32)  # one class everywhere
    fc = FederatedConfig(protocol="mix2fld", num_devices=4, n_seed=6,
                         n_inverse=12)
    seeds = collect_seeds(fc, dev_x, dev_y, key)
    assert seeds["train_y"].ndim == 2  # soft-label fallback
    assert bool(jnp.isfinite(seeds["train_x"]).all())


def test_cycle_lams_pair_matrix_singular_at_half():
    """n = 2, lam = 0.5 is the Prop. 1 singularity (eigenvalue
    lam + (1-lam)*omega = 0): the circulant must NOT be invertible —
    this is exactly why collect_seeds degrades at lam = 0.5."""
    C = np.asarray(circulant(cycle_lams(2, 0.5)))
    assert abs(np.linalg.det(C)) < 1e-6


def test_inverse_mixup_cycles_odd_length_survives_lam_half():
    """Odd cycle lengths keep all eigenvalues lam + (1-lam)*omega^k away
    from zero even at lam = 0.5, so the general-N inverse still unmixes."""
    length, lam = 3, 0.5
    raw = np.random.default_rng(0).normal(
        size=(length, 8)).astype(np.float32)
    m = np.stack([lam * raw[k] + (1 - lam) * raw[(k + 1) % length]
                  for k in range(length)])
    cycles = np.arange(length)[None, :]
    out = inverse_mixup_cycles(jnp.asarray(m), cycles, lam)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out), raw, atol=1e-3)


def test_find_label_cycles_dfs_budget_exhaustion_returns_partial():
    """A tiny step budget must terminate the DFS reference with whatever
    was found so far (graceful degradation), never hang or raise.  The
    default segment/sort path has no budget — the production-path
    guarantee (full yield where the DFS degrades) is covered by
    tests/test_cycle_search.py."""
    from repro.core.mixup import find_label_cycles_dfs
    rng = np.random.default_rng(2)
    n, C, D = 400, 10, 40
    minor = rng.integers(0, C, n)
    major = (minor + rng.integers(1, C, n)) % C
    dev = rng.integers(0, D, n)
    full = find_label_cycles_dfs(minor, major, dev, 3)
    assert len(full) > 1  # solvable graph
    tiny = find_label_cycles_dfs(minor, major, dev, 3, max_steps=4)
    assert len(tiny) < len(full)  # budget cut the search short
    assert tiny.shape[1:] == (3,)
    for row in tiny:  # whatever was found is still valid
        for k in range(3):
            assert major[row[k]] == minor[row[(k + 1) % 3]]
    zero = find_label_cycles_dfs(minor, major, dev, 3, max_steps=0)
    assert len(zero) == 0


# ---------------------------------------------------------------------------
# Privacy ordering (Table II)
# ---------------------------------------------------------------------------

def test_mixup_improves_sample_privacy():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (40, 16))
    y = jnp.concatenate([jnp.zeros(20, jnp.int32), jnp.ones(20, jnp.int32)])
    i, j = mixup_pairs(key, y, 16, 2)
    lo, _, _ = make_mixup_batch(x, y, i, j, 0.01, 2)
    hi, _, _ = make_mixup_batch(x, y, i, j, 0.4, 2)
    raws = jnp.stack([x[i], x[j]], axis=1)
    # lambda closer to 0.5 mixes more evenly => more private (Table II)
    assert float(jnp.mean(sample_privacy(hi, raws))) > \
        float(jnp.mean(sample_privacy(lo, raws)))
