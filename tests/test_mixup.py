"""Property tests (hypothesis) for Mixup / inverse-Mixup (Prop. 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.mixup import (circulant, inverse_mixup, inverse_mixup_n,
                              inverse_mixup_ratios, make_mixup_batch,
                              mixup_pairs, pair_symmetric)
from repro.core.privacy import sample_privacy


@st.composite
def mixing_ratios(draw, n):
    """Well-conditioned ratio vectors on the simplex (away from the
    singular uniform point)."""
    raw = [draw(st.floats(0.05, 1.0)) for _ in range(n)]
    lams = np.array(raw) / np.sum(raw)
    cond = np.linalg.cond(np.asarray(circulant(jnp.asarray(lams))))
    if not np.isfinite(cond) or cond > 1e3:
        raw[0] += 1.0
        lams = np.array(raw) / np.sum(raw)
    return lams


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 5), st.data())
def test_prop1_inverse_is_matrix_inverse(n, data):
    lams = data.draw(mixing_ratios(n))
    C = circulant(jnp.asarray(lams, jnp.float32))
    R = inverse_mixup_ratios(jnp.asarray(lams, jnp.float32))
    np.testing.assert_allclose(np.asarray(R @ C), np.eye(n), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(st.floats(0.01, 0.45))
def test_inverse_mixup_recovers_hard_labels(lam):
    a = jnp.array([1.0, 0.0])
    b = jnp.array([0.0, 1.0])
    mixed_a = lam * a + (1 - lam) * b
    mixed_b = lam * b + (1 - lam) * a
    s1, s2 = inverse_mixup(mixed_a, mixed_b, lam)
    np.testing.assert_allclose(np.asarray(s1), [1.0, 0.0], atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), [0.0, 1.0], atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.floats(0.05, 0.45), st.integers(0, 1000))
def test_inverse_mixup_on_samples_not_equal_raw(lam, seed):
    """Inversely mixed samples recover the LABEL but (for cross-device
    pairs with different raw content) not the raw SAMPLE."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    xa1, xa2 = jax.random.normal(k1, (8,)), jax.random.normal(k2, (8,))
    xb1, xb2 = jax.random.normal(k3, (8,)), jax.random.normal(k4, (8,))
    # device a mixes (class0, class1); device b mixes (class1, class0)
    ma = lam * xa1 + (1 - lam) * xa2
    mb = lam * xb1 + (1 - lam) * xb2
    s1, s2 = inverse_mixup(ma, mb, lam)
    for s in (s1, s2):
        for raw in (xa1, xa2, xb1, xb2):
            assert float(jnp.linalg.norm(s - raw)) > 1e-4


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 6), st.integers(0, 99))
def test_inverse_mixup_n_unmixes_cyclic_stack(n, seed):
    lams = np.linspace(1, 2, n)
    lams /= lams.sum()
    key = jax.random.PRNGKey(seed)
    raw = jax.random.normal(key, (n, 5))
    C = np.asarray(circulant(jnp.asarray(lams, jnp.float32)))
    mixed = jnp.asarray(C) @ raw
    rec = inverse_mixup_n(mixed, jnp.asarray(lams, jnp.float32))
    np.testing.assert_allclose(np.asarray(rec), np.asarray(raw), atol=1e-2)


def test_mixup_pairs_have_different_labels():
    key = jax.random.PRNGKey(0)
    labels = jax.random.randint(key, (200,), 0, 10)
    i, j = mixup_pairs(key, labels, 64, 10)
    assert bool(jnp.all(labels[i] != labels[j]))


def test_make_mixup_batch_soft_labels_sum_to_one():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (50, 4))
    y = jax.random.randint(key, (50,), 0, 10)
    i, j = mixup_pairs(key, y, 20, 10)
    mixed, soft, (mi, ma) = make_mixup_batch(x, y, i, j, 0.3, 10)
    np.testing.assert_allclose(np.asarray(jnp.sum(soft, -1)), 1.0, atol=1e-5)
    assert mixed.shape == (20, 4)


def test_pair_symmetric_matches_reversed_pairs_across_devices():
    minor = np.array([0, 1, 2, 1, 0])
    major = np.array([1, 0, 3, 0, 1])
    dev = np.array([0, 1, 0, 0, 0])
    pairs = pair_symmetric(minor, major, dev)
    for i, j in pairs:
        assert minor[i] == major[j] and major[i] == minor[j]
        assert dev[i] != dev[j]


def test_mixup_improves_sample_privacy():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (40, 16))
    y = jnp.concatenate([jnp.zeros(20, jnp.int32), jnp.ones(20, jnp.int32)])
    i, j = mixup_pairs(key, y, 16, 2)
    lo, _, _ = make_mixup_batch(x, y, i, j, 0.01, 2)
    hi, _, _ = make_mixup_batch(x, y, i, j, 0.4, 2)
    raws = jnp.stack([x[i], x[j]], axis=1)
    # lambda closer to 0.5 mixes more evenly => more private (Table II)
    assert float(jnp.mean(sample_privacy(hi, raws))) > \
        float(jnp.mean(sample_privacy(lo, raws)))
