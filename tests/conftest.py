"""Shared pytest wiring: the ``multichip`` marker auto-skips on 1-chip
hosts, so pod-validation assertions ride in the suite without breaking
CPU containers (run them on a TPU pod to validate real sharding)."""
import pytest


def pytest_collection_modifyitems(config, items):
    import jax
    if jax.device_count() > 1:
        return
    skip = pytest.mark.skip(reason="needs >1 accelerator chip "
                                   f"(found {jax.device_count()})")
    for item in items:
        if "multichip" in item.keywords:
            item.add_marker(skip)
