"""End-to-end driver smoke tests (examples/launch entry points)."""
import jax
import jax.numpy as jnp


def test_serve_driver_generates(capsys):
    from repro.launch.serve import serve
    out = serve("qwen2-0.5b", batch=2, prompt_len=16, gen=4, smoke=True,
                log=lambda *a: None)
    assert out.shape == (2, 4)
    assert bool(jnp.all(out >= 0))


def test_lm_train_driver_loss_decreases():
    import repro.launch.train as T

    class Args:
        arch = "qwen2-0.5b"
        preset = "25m"
        pods = 2
        steps = 8
        batch = 2
        seq = 32
        sync_every = 4
        ks_iters = 1
        log_every = 100
        ckpt_dir = ""

    # shrink the preset further for CI speed
    orig = T._preset

    def tiny(cfg, preset):
        import dataclasses
        return dataclasses.replace(
            cfg, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
            head_dim=32, d_ff=256, vocab_size=512, param_dtype="float32",
            fd_buckets=32, max_position=1024, num_experts=0,
            num_shared_experts=0, top_k=0, moe_d_ff=0)

    T._preset = tiny
    try:
        pod_params = T.run_lm(Args)
    finally:
        T._preset = orig
    assert pod_params is not None
    for leaf in jax.tree.leaves(pod_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
