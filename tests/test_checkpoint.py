"""Checkpoint save/restore round-trips and crash-safety hardening."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.models.transformer import init_params


def test_roundtrip(tmp_path):
    cfg = get_config("qwen2-0.5b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, params)
    assert ckpt.latest_step(d) == 3
    zeros = jax.tree.map(jnp.zeros_like, params)
    restored = ckpt.restore(d, zeros)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_pointer_advances(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(4.0), "b": {"c": jnp.ones((2, 2))}}
    ckpt.save(d, 1, tree)
    tree2 = {"a": jnp.arange(4.0) * 2, "b": {"c": jnp.zeros((2, 2))}}
    ckpt.save(d, 2, tree2)
    out = ckpt.restore(d, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree2["a"]))


def test_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"a": jnp.ones((3,))})
    with pytest.raises(ValueError):
        ckpt.restore(d, {"a": jnp.ones((4,))})


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"), {"a": jnp.ones(1)})


# ---- LATEST pointer hardening -------------------------------------------


def _corrupt_latest(d, content):
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write(content)


@pytest.mark.parametrize("content", ["", "garbage", "step_", "step_00x1"])
def test_corrupt_latest_falls_back_to_scan(tmp_path, content):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(3.0)}
    ckpt.save(d, 1, tree)
    ckpt.save(d, 4, {"a": jnp.arange(3.0) * 4})
    _corrupt_latest(d, content)
    assert ckpt.latest_step(d) == 4
    out = ckpt.restore(d, {"a": jnp.zeros(3)})
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.arange(3.0) * 4)


def test_stale_latest_pointing_at_missing_dir_falls_back(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 2, {"a": jnp.ones(2)})
    _corrupt_latest(d, "step_00000099")
    assert ckpt.latest_step(d) == 2


def test_missing_latest_falls_back_to_scan(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, {"a": jnp.ones(2)})
    os.remove(os.path.join(d, "LATEST"))
    assert ckpt.latest_step(d) == 7


def test_latest_step_missing_dir_returns_none(tmp_path):
    assert ckpt.latest_step(str(tmp_path / "nope")) is None


# ---- crashed-save GC + atomicity ----------------------------------------


def test_orphaned_tmp_dirs_are_collected_on_next_save(tmp_path):
    d = str(tmp_path / "ck")
    os.makedirs(d)
    os.makedirs(os.path.join(d, "tmpdeadbeef"))
    with open(os.path.join(d, "tmpdeadbeef", "arrays.npz"), "wb") as f:
        f.write(b"partial")
    with open(os.path.join(d, "tmporphanfile"), "w") as f:
        f.write("x")
    ckpt.save(d, 1, {"a": jnp.ones(2)})
    names = os.listdir(d)
    assert not any(n.startswith("tmp") for n in names)
    assert ckpt.latest_step(d) == 1


def test_crash_mid_save_leaves_previous_checkpoint_usable(tmp_path,
                                                          monkeypatch):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(4.0)}
    ckpt.save(d, 1, tree)

    def boom(*a, **k):
        raise RuntimeError("disk died")

    monkeypatch.setattr(ckpt.np, "savez", boom)
    with pytest.raises(RuntimeError):
        ckpt.save(d, 2, {"a": jnp.arange(4.0) * 2})
    monkeypatch.undo()
    # the failed save must not have advanced the pointer or left litter
    # that breaks a subsequent restore
    assert ckpt.latest_step(d) == 1
    out = ckpt.restore(d, {"a": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(4.0))
    ckpt.save(d, 2, {"a": jnp.arange(4.0) * 2})
    assert not any(n.startswith("tmp") for n in os.listdir(d))
    assert ckpt.latest_step(d) == 2


# ---- retention ----------------------------------------------------------


def test_retention_keeps_last_k(tmp_path):
    d = str(tmp_path / "ck")
    for step in range(1, 6):
        ckpt.save(d, step, {"a": jnp.full((2,), float(step))}, keep=2)
    assert ckpt.steps(d) == [4, 5]
    assert ckpt.latest_step(d) == 5
    out = ckpt.restore(d, {"a": jnp.zeros(2)}, step=4)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.full(2, 4.0))


def test_retention_rejects_nonpositive_keep(tmp_path):
    with pytest.raises(ValueError):
        ckpt.save(str(tmp_path / "ck"), 1, {"a": jnp.ones(1)}, keep=0)


# ---- structural validation ----------------------------------------------


def test_path_mismatch_same_shapes_raises_with_diff(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"w1": jnp.ones((3,)), "w2": jnp.zeros((3,))})
    with pytest.raises(ValueError, match="w3"):
        # equal leaf count, identical shapes — pre-hardening this
        # silently loaded w2's data into w3
        ckpt.restore(d, {"w1": jnp.ones((3,)), "w3": jnp.zeros((3,))})


def test_nested_path_mismatch_lists_both_sides(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"m": {"a": jnp.ones(2)}, "b": jnp.zeros(2)})
    with pytest.raises(ValueError) as ei:
        ckpt.restore(d, {"m": {"z": jnp.ones(2)}, "b": jnp.zeros(2)})
    assert "m/a" in str(ei.value) and "m/z" in str(ei.value)


def test_shape_mismatch_names_the_leaf(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"a": jnp.ones((3,)), "b": jnp.ones((2, 2))})
    with pytest.raises(ValueError, match="'b'"):
        ckpt.restore(d, {"a": jnp.ones((3,)), "b": jnp.ones((2, 3))})


# ---- meta + template-free restore ---------------------------------------


def test_meta_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    meta = {"round": 12, "dp_rounds": 7, "converged_round": None}
    ckpt.save(d, 12, {"a": jnp.ones(2)}, meta=meta)
    assert ckpt.load_meta(d) == meta
    assert ckpt.load_meta(d, step=12) == meta


def test_restore_tree_rebuilds_nested_dicts(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"g": {"w": np.arange(6.0).reshape(2, 3),
                  "b": np.zeros(3)},
            "round_key": np.array([1, 2], np.uint32)}
    ckpt.save(d, 5, tree, meta={"round": 5})
    out, meta = ckpt.restore_tree(d)
    assert meta == {"round": 5}
    np.testing.assert_array_equal(out["g"]["w"], tree["g"]["w"])
    np.testing.assert_array_equal(out["g"]["b"], tree["g"]["b"])
    np.testing.assert_array_equal(out["round_key"], tree["round_key"])
    assert out["round_key"].dtype == np.uint32


def test_restore_tree_bare_array(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, np.arange(5.0))
    out, _ = ckpt.restore_tree(d)
    np.testing.assert_array_equal(out, np.arange(5.0))
