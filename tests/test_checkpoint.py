"""Checkpoint save/restore round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.models.transformer import init_params


def test_roundtrip(tmp_path):
    cfg = get_config("qwen2-0.5b").smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    d = str(tmp_path / "ck")
    ckpt.save(d, 3, params)
    assert ckpt.latest_step(d) == 3
    zeros = jax.tree.map(jnp.zeros_like, params)
    restored = ckpt.restore(d, zeros)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_pointer_advances(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(4.0), "b": {"c": jnp.ones((2, 2))}}
    ckpt.save(d, 1, tree)
    tree2 = {"a": jnp.arange(4.0) * 2, "b": {"c": jnp.zeros((2, 2))}}
    ckpt.save(d, 2, tree2)
    out = ckpt.restore(d, jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree2["a"]))


def test_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 1, {"a": jnp.ones((3,))})
    with pytest.raises(ValueError):
        ckpt.restore(d, {"a": jnp.ones((4,))})


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "nope"), {"a": jnp.ones(1)})
