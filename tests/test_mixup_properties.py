"""Property tests (hypothesis) for Mixup / inverse-Mixup (Prop. 1).

Skipped entirely when ``hypothesis`` is not installed (install the
``test`` extra); deterministic parametrized equivalents of every property
here live in ``test_mixup.py`` and always run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.mixup import (circulant, find_label_cycles, inverse_mixup,
                              inverse_mixup_cycles, inverse_mixup_n,
                              inverse_mixup_ratios)


@st.composite
def mixing_ratios(draw, n):
    """Well-conditioned ratio vectors on the simplex (away from the
    singular uniform point)."""
    raw = [draw(st.floats(0.05, 1.0)) for _ in range(n)]
    lams = np.array(raw) / np.sum(raw)
    cond = np.linalg.cond(np.asarray(circulant(jnp.asarray(lams))))
    if not np.isfinite(cond) or cond > 1e3:
        raw[0] += 1.0
        lams = np.array(raw) / np.sum(raw)
    return lams


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 5), st.data())
def test_prop1_inverse_is_matrix_inverse(n, data):
    lams = data.draw(mixing_ratios(n))
    C = circulant(jnp.asarray(lams, jnp.float32))
    R = inverse_mixup_ratios(jnp.asarray(lams, jnp.float32))
    np.testing.assert_allclose(np.asarray(R @ C), np.eye(n), atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(st.floats(0.01, 0.45))
def test_inverse_mixup_recovers_hard_labels(lam):
    a = jnp.array([1.0, 0.0])
    b = jnp.array([0.0, 1.0])
    mixed_a = lam * a + (1 - lam) * b
    mixed_b = lam * b + (1 - lam) * a
    s1, s2 = inverse_mixup(mixed_a, mixed_b, lam)
    np.testing.assert_allclose(np.asarray(s1), [1.0, 0.0], atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), [0.0, 1.0], atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.floats(0.05, 0.45), st.integers(0, 1000))
def test_inverse_mixup_on_samples_not_equal_raw(lam, seed):
    """Inversely mixed samples recover the LABEL but (for cross-device
    pairs with different raw content) not the raw SAMPLE."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    xa1, xa2 = jax.random.normal(k1, (8,)), jax.random.normal(k2, (8,))
    xb1, xb2 = jax.random.normal(k3, (8,)), jax.random.normal(k4, (8,))
    # device a mixes (class0, class1); device b mixes (class1, class0)
    ma = lam * xa1 + (1 - lam) * xa2
    mb = lam * xb1 + (1 - lam) * xb2
    s1, s2 = inverse_mixup(ma, mb, lam)
    for s in (s1, s2):
        for raw in (xa1, xa2, xb1, xb2):
            assert float(jnp.linalg.norm(s - raw)) > 1e-4


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 6), st.integers(0, 99))
def test_inverse_mixup_n_unmixes_cyclic_stack(n, seed):
    lams = np.linspace(1, 2, n)
    lams /= lams.sum()
    key = jax.random.PRNGKey(seed)
    raw = jax.random.normal(key, (n, 5))
    C = np.asarray(circulant(jnp.asarray(lams, jnp.float32)))
    mixed = jnp.asarray(C) @ raw
    rec = inverse_mixup_n(mixed, jnp.asarray(lams, jnp.float32))
    np.testing.assert_allclose(np.asarray(rec), np.asarray(raw), atol=1e-2)


@settings(max_examples=15, deadline=None)
@given(st.integers(3, 6), st.floats(0.05, 0.45), st.integers(0, 99))
def test_cycle_unmix_recovers_constructed_cycle(length, lam, seed):
    rng = np.random.default_rng(seed)
    raw = rng.normal(size=(length, 12)).astype(np.float32)
    m = np.stack([lam * raw[k] + (1 - lam) * raw[(k + 1) % length]
                  for k in range(length)])
    minor = np.arange(length)
    major = (minor + 1) % length
    cycles = find_label_cycles(minor, major, np.arange(length), length)
    assert cycles.shape == (1, length)
    out = inverse_mixup_cycles(jnp.asarray(m), cycles, lam)
    np.testing.assert_allclose(np.asarray(out), raw[cycles.reshape(-1)],
                               atol=2e-3)
