"""Unit tests for the loop-aware HLO collective analyzer."""
import textwrap

from repro.configs import INPUT_SHAPES, get_config
from repro.roofline.analysis import (_crosses_pods, _shape_bytes,
                                     analytic_flops,
                                     collective_bytes_from_hlo,
                                     dominant_term, roofline_terms)

HLO = textwrap.dedent("""
    HloModule test

    %inner_body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
      %ag1 = f32[64,4]{1,0} all-gather(%x), replica_groups=[4,4]<=[16]
      ROOT %t = (s32[], f32[8]) tuple(%i, %y)
    }

    %outer_body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
      %ar1 = f32[32]{0} all-reduce(%g), replica_groups={{0,1},{2,3}}
      %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%inner_body, backend_config={"known_trip_count":{"n":"3"}}
      ROOT %t2 = (s32[], f32[8]) tuple(%i, %y)
    }

    ENTRY %main (a: f32[8]) -> f32[8] {
      %big = bf16[128,128]{1,0} all-gather(%a), replica_groups=[2,8]<=[16]
      %w0 = (s32[], f32[8]) while(%t), condition=%c, body=%outer_body, backend_config={"known_trip_count":{"n":"5"}}
      ROOT %r = f32[8] add(%a, %a)
    }
""")


def test_shape_bytes():
    assert _shape_bytes("f32[64,4]") == 64 * 4 * 4
    assert _shape_bytes("bf16[128,128]") == 128 * 128 * 2
    assert _shape_bytes("(f32[2,2], bf16[4])") == 16 + 8


def test_loop_aware_collective_totals():
    out = collective_bytes_from_hlo(HLO)
    # entry: 1 bf16 gather (32768 B)
    # outer x5: all-reduce 128 B + inner x3: all-gather 1024 B
    want_ag = 128 * 128 * 2 + 5 * 3 * 64 * 4 * 4
    want_ar = 5 * 32 * 4
    assert out["all-gather"] == want_ag
    assert out["all-reduce"] == want_ar
    assert out["total"] == want_ag + want_ar
    assert out["counts"]["all-gather"] == 1 + 15
    assert out["counts"]["all-reduce"] == 5


def test_cross_pod_classification():
    # iota groups [4,4]<=[16]: rows 0-3,4-7,... with pod_size 8: intra
    assert not _crosses_pods(
        "all-gather(%x), replica_groups=[4,4]<=[16]", 8)
    # [2,8]<=[16]: rows 0..7 / 8..15 with pod_size 4: crosses
    assert _crosses_pods(
        "all-gather(%x), replica_groups=[2,8]<=[16]", 4)
    # explicit groups
    assert _crosses_pods("all-reduce(%g), replica_groups={{0,9}}", 8)
    assert not _crosses_pods("all-reduce(%g), replica_groups={{0,1},{8,9}}",
                             8)
    # collective-permute pairs
    assert _crosses_pods("collective-permute(%x), source_target_pairs={{0,8}}",
                         8)
    assert not _crosses_pods(
        "collective-permute(%x), source_target_pairs={{0,1},{8,9}}", 8)


def test_cross_pod_counted_through_loops():
    out = collective_bytes_from_hlo(HLO, pod_size=4)
    # entry bf16 gather crosses pods (groups of 8 > pod 4); inner f32
    # gathers have groups of 4 spanning ids 0-3 (intra for pod 4? rows are
    # 0..3 -> intra); outer all-reduce groups {0,1},{2,3} intra
    assert out["cross_pod"] == 128 * 128 * 2


def test_roofline_terms_and_dominance():
    t = roofline_terms(1e12, 1e9, 1e8, 1, 197e12, 819e9, 50e9)
    assert dominant_term(t) == "compute"
    t2 = roofline_terms(1e9, 1e9, 1e12, 1, 197e12, 819e9, 50e9)
    assert dominant_term(t2) == "collective"


def test_analytic_flops_scales_with_arch():
    shape = INPUT_SHAPES["train_4k"]
    small = analytic_flops(get_config("qwen2-0.5b"), shape, 500_000_000)
    big = analytic_flops(get_config("qwen2-vl-72b"), shape, 72_000_000_000)
    assert big > 50 * small / 500 * 72  # grows at least with N
    # decode flops are ~tokens-per-step smaller
    dec = analytic_flops(get_config("qwen2-0.5b"),
                         INPUT_SHAPES["decode_32k"], 500_000_000)
    assert dec < small / 1000
