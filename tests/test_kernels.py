"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.distill_loss import distill_loss_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mixup_kernel import mixup_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


@pytest.mark.parametrize("n,f", [(8, 64), (100, 784), (256, 512), (33, 17)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mixup_kernel_matches_ref(n, f, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    a = jax.random.normal(k1, (n, f), dtype)
    b = jax.random.normal(k2, (n, f), dtype)
    la = jax.random.uniform(k3, (n,))
    lb = 1.0 - la
    got = mixup_pallas(a, b, la, lb)
    want = ref.mixup_ref(a, b, la, lb)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-6)


def test_inverse_mixup_kernel_roundtrip():
    key = jax.random.PRNGKey(1)
    raw_a = jax.random.normal(key, (16, 49))
    raw_b = jax.random.normal(jax.random.fold_in(key, 1), (16, 49))
    lam = 0.2
    mixed_a = lam * raw_a + (1 - lam) * raw_b
    mixed_b = lam * raw_b + (1 - lam) * raw_a
    s1, s2 = ops.inverse_mixup_pair(mixed_a, mixed_b, lam)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(raw_a), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(raw_b), atol=1e-4)


@pytest.mark.parametrize("n,c", [(16, 10), (128, 10), (50, 257), (300, 64)])
def test_distill_loss_matches_ref(n, c):
    k = jax.random.PRNGKey(2)
    logits = jax.random.normal(k, (n, c)) * 3
    labels = jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, c)
    g = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 2), (n, c)))
    got = distill_loss_pallas(logits, labels, g, 0.01)
    want = ref.distill_loss_ref(logits, labels, g, 0.01)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)


def test_distill_loss_agrees_with_core_fd_loss():
    """Kernel mean == repro.core.losses.fd_loss on the same batch."""
    from repro.core.losses import fd_loss
    k = jax.random.PRNGKey(3)
    logits = jax.random.normal(k, (64, 10))
    labels = jax.random.randint(jax.random.fold_in(k, 1), (64,), 0, 10)
    gout = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 2),
                                            (10, 10)))
    got = ops.distill_loss(logits, labels, gout, 0.01)
    want, _ = fd_loss(logits, labels, gout, 0.01)
    np.testing.assert_allclose(float(got), float(want), rtol=2e-5)


@pytest.mark.parametrize("bh,s,d", [(2, 256, 64), (4, 512, 32), (1, 512, 128)])
@pytest.mark.parametrize("window", [None, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(bh, s, d, window, dtype):
    k = jax.random.PRNGKey(4)
    q = jax.random.normal(k, (bh, s, d), dtype)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (bh, s, d), dtype)
    v = jax.random.normal(jax.random.fold_in(k, 2), (bh, s, d), dtype)
    got = flash_attention_pallas(q, kk, v, window=window, blk_q=128,
                                 blk_k=128)
    want = ref.attention_ref(q, kk, v, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (2, 128, 32, 16, 32), (4, 256, 64, 32, 64), (1, 64, 16, 8, 16)])
def test_ssd_scan_matches_sequential_ref(bh, s, p, n, chunk):
    k = jax.random.PRNGKey(5)
    xdt = jax.random.normal(k, (bh, s, p)) * 0.5
    B = jax.random.normal(jax.random.fold_in(k, 1), (bh, s, n)) * 0.5
    C = jax.random.normal(jax.random.fold_in(k, 2), (bh, s, n)) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 3),
                                            (bh, s)))
    got = ssd_scan_pallas(xdt, B, C, dA, chunk=chunk)
    want = ref.ssd_ref(xdt, B, C, dA)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=1e-3)


def test_model_ssd_chunked_matches_sequential_ref():
    """The model's chunked SSD (mamba2.ssd_chunked) vs the recurrence."""
    k = jax.random.PRNGKey(6)
    B_, S, H, P, G, N = 2, 96, 4, 16, 1, 8
    x = jax.random.normal(k, (B_, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1),
                                           (B_, S, H)))
    A = -jnp.ones((H,)) * 0.5
    Bm = jax.random.normal(jax.random.fold_in(k, 2), (B_, S, G, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(k, 3), (B_, S, G, N)) * 0.5
    from repro.models.mamba2 import ssd_chunked
    y, final = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    # sequential reference in the kernel layout
    xdt = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(B_ * H, S, P)
    Bh = jnp.repeat(Bm, H // G, 2).transpose(0, 2, 1, 3).reshape(B_ * H, S, N)
    Ch = jnp.repeat(Cm, H // G, 2).transpose(0, 2, 1, 3).reshape(B_ * H, S, N)
    dA = (dt * A).transpose(0, 2, 1).reshape(B_ * H, S)
    want = ref.ssd_ref(xdt, Bh, Ch, dA).reshape(B_, H, S, P) \
        .transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=2e-4, rtol=1e-3)


def test_ssd_kernel_state_isolated_between_batch_rows():
    """Scratch state must reset per (b,h) grid row."""
    k = jax.random.PRNGKey(7)
    xdt = jax.random.normal(k, (3, 64, 8))
    B = jax.random.normal(jax.random.fold_in(k, 1), (3, 64, 4))
    C = jax.random.normal(jax.random.fold_in(k, 2), (3, 64, 4))
    dA = -jnp.abs(jax.random.normal(jax.random.fold_in(k, 3), (3, 64)))
    full = ssd_scan_pallas(xdt, B, C, dA, chunk=16)
    solo = ssd_scan_pallas(xdt[1:2], B[1:2], C[1:2], dA[1:2], chunk=16)
    np.testing.assert_allclose(np.asarray(full[1]), np.asarray(solo[0]),
                               atol=1e-5)
