"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.distill_loss import distill_loss_pallas, distill_phi_psi
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mixup_kernel import mixup_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


@pytest.mark.parametrize("n,f", [(8, 64), (100, 784), (256, 512), (33, 17)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mixup_kernel_matches_ref(n, f, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    a = jax.random.normal(k1, (n, f), dtype)
    b = jax.random.normal(k2, (n, f), dtype)
    la = jax.random.uniform(k3, (n,))
    lb = 1.0 - la
    got = mixup_pallas(a, b, la, lb)
    want = ref.mixup_ref(a, b, la, lb)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-6)


def test_inverse_mixup_kernel_roundtrip():
    key = jax.random.PRNGKey(1)
    raw_a = jax.random.normal(key, (16, 49))
    raw_b = jax.random.normal(jax.random.fold_in(key, 1), (16, 49))
    lam = 0.2
    mixed_a = lam * raw_a + (1 - lam) * raw_b
    mixed_b = lam * raw_b + (1 - lam) * raw_a
    s1, s2 = ops.inverse_mixup_pair(mixed_a, mixed_b, lam)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(raw_a), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(raw_b), atol=1e-4)


@pytest.mark.parametrize("n,c", [(16, 10), (128, 10), (50, 257), (300, 64)])
def test_distill_loss_matches_ref(n, c):
    k = jax.random.PRNGKey(2)
    logits = jax.random.normal(k, (n, c)) * 3
    labels = jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, c)
    g = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 2), (n, c)))
    got = distill_loss_pallas(logits, labels, g, 0.01)
    want = ref.distill_loss_ref(logits, labels, g, 0.01)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5)


def test_distill_loss_agrees_with_core_fd_loss():
    """Kernel mean == repro.core.losses.fd_loss on the same batch."""
    from repro.core.losses import fd_loss
    k = jax.random.PRNGKey(3)
    logits = jax.random.normal(k, (64, 10))
    labels = jax.random.randint(jax.random.fold_in(k, 1), (64,), 0, 10)
    gout = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 2),
                                            (10, 10)))
    got = ops.distill_loss(logits, labels, gout, 0.01)
    want, _ = fd_loss(logits, labels, gout, 0.01)
    np.testing.assert_allclose(float(got), float(want), rtol=2e-5)


# ---------------------------------------------------------------------------
# Hot-path parity: the fused phi/psi custom_vjp pair behind fd_loss and the
# device-side Mixup kernel behind collect_seeds, vs their jnp references
# (interpret mode on CPU; shapes include non-divisible row/col blocks)
# ---------------------------------------------------------------------------

def _fd_batch(n, c, seed=0):
    k = jax.random.PRNGKey(seed)
    logits = jax.random.normal(k, (n, c)) * 3
    labels = jax.random.randint(jax.random.fold_in(k, 1), (n,), 0, c)
    gout = jax.nn.softmax(
        jax.random.normal(jax.random.fold_in(k, 2), (c, c)) * 2)
    return logits, labels, gout


# 100/300 break ROW_BLOCK=128; 257/33 are odd class/class-row dims
@pytest.mark.parametrize("n,c", [(16, 10), (128, 10), (100, 33),
                                 (300, 64), (50, 257)])
def test_fd_loss_kernel_value_parity(n, c):
    from repro.core.losses import fd_loss
    logits, labels, gout = _fd_batch(n, c)
    got, (gphi, gpsi) = fd_loss(logits, labels, gout, 0.01)
    want, (wphi, wpsi) = fd_loss(logits, labels, gout, 0.01,
                                 use_kernel=False)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
    np.testing.assert_allclose(float(gphi), float(wphi), rtol=1e-5)
    np.testing.assert_allclose(float(gpsi), float(wpsi), rtol=1e-5)


@pytest.mark.parametrize("n,c", [(16, 10), (100, 33), (300, 64)])
@pytest.mark.parametrize("beta", [0.0, 0.01, 0.5])
def test_fd_loss_kernel_grad_parity(n, c, beta):
    """custom_vjp backward kernel vs jax-derived reference gradients, in
    both differentiable arguments (logits and the G_out table through the
    row gather)."""
    from repro.core.losses import fd_loss
    logits, labels, gout = _fd_batch(n, c, seed=1)

    for arg in (0, 1):
        gk = jax.grad(lambda l, g: fd_loss(l, labels, g, beta)[0],
                      argnums=arg)(logits, gout)
        gr = jax.grad(
            lambda l, g: fd_loss(l, labels, g, beta, use_kernel=False)[0],
            argnums=arg)(logits, gout)
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                                   atol=1e-5, rtol=1e-5)


def test_fd_loss_kernel_unnormalised_gout_rows():
    """psi carries the exact sum(g)*lse term: zero / unnormalised G_out
    rows (classes never observed in eq. 2) must still match the jnp
    reference, not assume sum(g) = 1."""
    from repro.core.losses import fd_loss
    logits, labels, gout = _fd_batch(64, 10, seed=2)
    gout = gout.at[::2].set(0.0)  # half the rows zeroed
    got, _ = fd_loss(logits, labels, gout, 0.3)
    want, _ = fd_loss(logits, labels, gout, 0.3, use_kernel=False)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
    gk = jax.grad(lambda l: fd_loss(l, labels, gout, 0.3)[0])(logits)
    gr = jax.grad(lambda l: fd_loss(l, labels, gout, 0.3,
                                    use_kernel=False)[0])(logits)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), atol=1e-5)


def test_fd_loss_kernel_under_vmap_scan_value_and_grad():
    """The exact hot-path composition: fd_loss under value_and_grad inside
    a scan, vmapped over the device axis (what _local_train traces)."""
    from repro.core.losses import fd_loss
    d, b, c = 3, 16, 10
    k = jax.random.PRNGKey(4)
    logits = jax.random.normal(k, (d, b, c))
    labels = jax.random.randint(jax.random.fold_in(k, 1), (d, b), 0, c)
    gout = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 2),
                                            (c, c)))

    def device_loss(use_kernel):
        def per_device(lg, lb):
            def body(carry, _):
                l, g = jax.value_and_grad(
                    lambda z: fd_loss(z, lb, gout, 0.1,
                                      use_kernel=use_kernel)[0])(lg)
                return carry + l, g
            tot, gs = jax.lax.scan(body, 0.0, jnp.arange(2))
            return tot, gs
        return jax.vmap(per_device)(logits, labels)

    tk, gk = device_loss(True)
    tr, gr = device_loss(False)
    np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), atol=1e-5)


def test_distill_phi_psi_per_sample_values():
    """Per-sample (phi, psi) vs a hand-rolled jnp computation."""
    logits, labels, gout = _fd_batch(37, 12, seed=5)
    g_rows = gout[labels]
    phi, psi = distill_phi_psi(logits, labels, g_rows)
    lse = jax.nn.logsumexp(logits, axis=-1)
    wphi = lse - jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
    wpsi = (jnp.sum(g_rows, -1) * lse - jnp.sum(g_rows * logits, -1))
    np.testing.assert_allclose(np.asarray(phi), np.asarray(wphi),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(psi), np.asarray(wpsi),
                               rtol=1e-5, atol=1e-6)


# (D, Ns, n_local) shapes chosen so D*Ns and the flattened feature dim
# both miss the kernel's 256/512 block sizes
@pytest.mark.parametrize("d,ns,n_local", [(3, 7, 40), (5, 10, 64),
                                          (2, 3, 20)])
@pytest.mark.parametrize("lam", [0.2, 0.4])
def test_device_mixup_kernel_matches_vmapped_eq6(d, ns, n_local, lam):
    """make_mixup_batch_pallas (one kernel call over all D*Ns mixes) vs
    the vmapped jnp eq. 6 path it replaced on the seed-collection hot
    path — samples, soft labels and class metadata."""
    from repro.core.mixup import (make_mixup_batch, make_mixup_batch_pallas,
                                  mixup_pairs)
    c = 10
    k = jax.random.PRNGKey(6)
    dev_x = jax.random.uniform(k, (d, n_local, 9, 5, 1))
    dev_y = jax.random.randint(jax.random.fold_in(k, 1), (d, n_local), 0, c)
    keys = jax.random.split(jax.random.fold_in(k, 2), d)
    idx_i, idx_j = jax.vmap(mixup_pairs, in_axes=(0, 0, None, None))(
        keys, dev_y, ns, c)
    got_x, got_s, (got_mi, got_ma) = make_mixup_batch_pallas(
        dev_x, dev_y, idx_i, idx_j, lam, c)
    want_x, want_s, (want_mi, want_ma) = jax.vmap(
        make_mixup_batch, in_axes=(0, 0, 0, 0, None, None))(
        dev_x, dev_y, idx_i, idx_j, lam, c)
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(want_x),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_mi), np.asarray(want_mi))
    np.testing.assert_array_equal(np.asarray(got_ma), np.asarray(want_ma))


@pytest.mark.parametrize("bh,s,d", [(2, 256, 64), (4, 512, 32), (1, 512, 128)])
@pytest.mark.parametrize("window", [None, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(bh, s, d, window, dtype):
    k = jax.random.PRNGKey(4)
    q = jax.random.normal(k, (bh, s, d), dtype)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (bh, s, d), dtype)
    v = jax.random.normal(jax.random.fold_in(k, 2), (bh, s, d), dtype)
    got = flash_attention_pallas(q, kk, v, window=window, blk_q=128,
                                 blk_k=128)
    want = ref.attention_ref(q, kk, v, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (2, 128, 32, 16, 32), (4, 256, 64, 32, 64), (1, 64, 16, 8, 16)])
def test_ssd_scan_matches_sequential_ref(bh, s, p, n, chunk):
    k = jax.random.PRNGKey(5)
    xdt = jax.random.normal(k, (bh, s, p)) * 0.5
    B = jax.random.normal(jax.random.fold_in(k, 1), (bh, s, n)) * 0.5
    C = jax.random.normal(jax.random.fold_in(k, 2), (bh, s, n)) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 3),
                                            (bh, s)))
    got = ssd_scan_pallas(xdt, B, C, dA, chunk=chunk)
    want = ref.ssd_ref(xdt, B, C, dA)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=1e-3)


def test_model_ssd_chunked_matches_sequential_ref():
    """The model's chunked SSD (mamba2.ssd_chunked) vs the recurrence."""
    k = jax.random.PRNGKey(6)
    B_, S, H, P, G, N = 2, 96, 4, 16, 1, 8
    x = jax.random.normal(k, (B_, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1),
                                           (B_, S, H)))
    A = -jnp.ones((H,)) * 0.5
    Bm = jax.random.normal(jax.random.fold_in(k, 2), (B_, S, G, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(k, 3), (B_, S, G, N)) * 0.5
    from repro.models.mamba2 import ssd_chunked
    y, final = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    # sequential reference in the kernel layout
    xdt = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(B_ * H, S, P)
    Bh = jnp.repeat(Bm, H // G, 2).transpose(0, 2, 1, 3).reshape(B_ * H, S, N)
    Ch = jnp.repeat(Cm, H // G, 2).transpose(0, 2, 1, 3).reshape(B_ * H, S, N)
    dA = (dt * A).transpose(0, 2, 1).reshape(B_ * H, S)
    want = ref.ssd_ref(xdt, Bh, Ch, dA).reshape(B_, H, S, P) \
        .transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=2e-4, rtol=1e-3)


def test_ssd_kernel_state_isolated_between_batch_rows():
    """Scratch state must reset per (b,h) grid row."""
    k = jax.random.PRNGKey(7)
    xdt = jax.random.normal(k, (3, 64, 8))
    B = jax.random.normal(jax.random.fold_in(k, 1), (3, 64, 4))
    C = jax.random.normal(jax.random.fold_in(k, 2), (3, 64, 4))
    dA = -jnp.abs(jax.random.normal(jax.random.fold_in(k, 3), (3, 64)))
    full = ssd_scan_pallas(xdt, B, C, dA, chunk=16)
    solo = ssd_scan_pallas(xdt[1:2], B[1:2], C[1:2], dA[1:2], chunk=16)
    np.testing.assert_allclose(np.asarray(full[1]), np.asarray(solo[0]),
                               atol=1e-5)
