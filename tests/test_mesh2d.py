"""2-D (grid x device) pod-mesh equivalence and shape resolution.

The sweep engine lays grid points along the ``"grid"`` axis and each
point's federated device axis along ``"data"`` (docs/pod_scale.md).
Grid points share no collectives — the psums stay over ``"data"`` — so
grid-axis sharding must be *bitwise* the vmapped program, while
device-axis sharding keeps the same reduction widths as the existing
1-D ``shard_devices`` path and must match it to 1e-6.

Comparisons are always reduction-width-matched: a (2, 4) mesh splits
device-axis sums into the same 4 partial sums as the 1-D 4-shard mesh,
so those two agree bitwise-or-epsilon on any host, whereas comparing
against the *unsharded* loop would measure float reassociation, not
correctness.  Shape-resolution tests are host-safe (pure arithmetic via
``avail=``); the sharded equivalence runs carry the ``multichip`` marker
and run on the CI job that forces 8 host devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import ChannelConfig
from repro.core.program import ProgramOptions
from repro.core.protocols import FederatedConfig
from repro.data import partition_iid, synthetic_images
from repro.launch.mesh import grid_mesh_shape, make_grid_mesh
from repro.launch.sharding import federated_grid_pspecs
from repro.models.cnn import CNN
from repro.sweep import SweepRunner, engine_stats, make_grid, run_sweep

CH = ChannelConfig(num_devices=4, p_up_dbm=40.0)


@pytest.fixture(scope="module")
def data():
    x, y = synthetic_images(jax.random.PRNGKey(42), 1400)
    dev_x, dev_y = partition_iid(np.asarray(x[:1200]),
                                 np.asarray(y[:1200]), 4, 300, 10, seed=0)
    return dev_x, dev_y, jnp.asarray(x[1200:]), jnp.asarray(y[1200:])


def _base(**kw):
    cfg = dict(protocol="mix2fld", num_devices=4, local_iters=8,
               local_batch=16, server_iters=8, server_batch=16,
               max_rounds=3, n_seed=6, n_inverse=12, seed=0)
    cfg.update(kw)
    return FederatedConfig(**cfg)


def _assert_match(res_a, res_b, n, atol=1e-6):
    for g in range(n):
        ha, hb = res_a.history(g), res_b.history(g)
        np.testing.assert_allclose(ha["acc"], hb["acc"], atol=atol,
                                   err_msg=f"acc, point {g}")
        np.testing.assert_allclose(ha["loss"], hb["loss"], atol=atol,
                                   err_msg=f"loss, point {g}")
        assert ha["uplink_ok"] == hb["uplink_ok"], f"uplink_ok, point {g}"
        assert ha["converged_round"] == hb["converged_round"], \
            f"converged_round, point {g}"


# ---------------------------------------------------------------------------
# Shape resolution: pure arithmetic, host-safe
# ---------------------------------------------------------------------------

def test_grid_mesh_shape_explicit_validates():
    assert grid_mesh_shape(6, 4, shape=(2, 2), avail=8) == (2, 2)
    with pytest.raises(ValueError, match="grid size"):
        grid_mesh_shape(6, 4, shape=(4, 1), avail=8)
    with pytest.raises(ValueError, match="device population"):
        grid_mesh_shape(6, 4, shape=(1, 3), avail=8)
    with pytest.raises(ValueError, match="chips"):
        grid_mesh_shape(2, 4, shape=(2, 4), avail=4)
    with pytest.raises(ValueError, match=">= 1"):
        grid_mesh_shape(2, 4, shape=(0, 4), avail=8)


def test_grid_mesh_shape_auto_spends_grid_axis_first():
    # grid points are collective-free, so chips go to "grid" greedily
    assert grid_mesh_shape(2, 4, avail=8) == (2, 4)
    assert grid_mesh_shape(6, 4, avail=8) == (6, 1)
    assert grid_mesh_shape(8, 4, avail=8) == (8, 1)
    # primes that don't fit stay unsharded on that axis
    assert grid_mesh_shape(5, 4, avail=4) == (1, 4)
    # the 1-chip degeneration every host path relies on
    assert grid_mesh_shape(6, 4, avail=1) == (1, 1)


def test_make_grid_mesh_axes():
    mesh = make_grid_mesh(6, 4)
    assert mesh.axis_names == ("grid", "data")
    gs, ds = mesh.devices.shape
    assert 6 % gs == 0 and 4 % ds == 0
    assert gs * ds <= len(jax.devices())


def test_federated_grid_pspecs_contract():
    specs = federated_grid_pspecs()
    assert specs["gdev"] == jax.sharding.PartitionSpec("grid", "data")
    assert specs["gcfg"] == jax.sharding.PartitionSpec("grid")
    assert specs["data"] == jax.sharding.PartitionSpec("data")
    assert specs["replicated"] == jax.sharding.PartitionSpec()


def test_runner_clamps_oversized_mesh_request(data):
    """A mesh request beyond the host's chips degrades to what divides
    and fits (budget semantics), instead of erroring — and the resolved
    shape is reported on the program."""
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(), CH, eta=(0.01, 0.02))
    runner = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty,
                         options=ProgramOptions(mesh_shape=(64, 64)))
    avail = len(jax.devices())
    for _, _, prog in runner._programs:
        gs, ds = prog.mesh_shape
        assert gs * ds <= avail
        assert 2 % gs == 0 and 4 % ds == 0


# ---------------------------------------------------------------------------
# Sharded equivalence on a real (forced 8-chip) multi-device host
# ---------------------------------------------------------------------------

@pytest.mark.multichip
def test_grid_axis_sharding_is_bitwise_vmapped(data):
    """Grid-axis-only sharding (2, 1): no collective anywhere touches a
    different operand set than the vmapped program, so the histories
    must match bitwise, not just to tolerance."""
    dev_x, dev_y, tx, ty = data
    grid_m = make_grid(_base(), CH, eta=(0.01, 0.02))
    runner = SweepRunner(CNN(), grid_m, dev_x, dev_y, tx, ty,
                         options=ProgramOptions(mesh_shape=(2, 1)))
    assert all(p.mesh_shape == (2, 1) for _, _, p in runner._programs)
    res_m = runner.run()
    grid_v = make_grid(_base(), CH, eta=(0.01, 0.02))
    res_v = run_sweep(CNN(), grid_v, dev_x, dev_y, tx, ty)
    for g in range(2):
        hm, hv = res_m.history(g), res_v.history(g)
        np.testing.assert_array_equal(hm["acc"], hv["acc"])
        np.testing.assert_array_equal(hm["loss"], hv["loss"])
        assert hm["uplink_ok"] == hv["uplink_ok"]
        assert hm["converged_round"] == hv["converged_round"]


@pytest.mark.multichip
def test_2d_mesh_matches_1d_device_sharding(data):
    """The full 2-D (2, 4) mesh against the pre-existing 1-D
    ``shard_devices`` path (4 device shards): identical psum widths on
    the device axis, so the grid axis must cost nothing numerically."""
    dev_x, dev_y, tx, ty = data
    grid_2d = make_grid(_base(), CH, eta=(0.01, 0.02))
    runner_2d = SweepRunner(CNN(), grid_2d, dev_x, dev_y, tx, ty,
                            options=ProgramOptions(mesh_shape=(2, 4)))
    assert all(p.mesh_shape == (2, 4) for _, _, p in runner_2d._programs)
    res_2d = runner_2d.run()
    grid_1d = make_grid(_base(shard_devices=True), CH, eta=(0.01, 0.02))
    runner_1d = SweepRunner(CNN(), grid_1d, dev_x, dev_y, tx, ty)
    assert runner_1d.mesh.devices.size == 4
    res_1d = runner_1d.run()
    _assert_match(res_2d, res_1d, 2)


@pytest.mark.multichip
def test_heterogeneous_sweep_on_2d_mesh_one_program_per_group(data):
    """A protocol-heterogeneous grid on the pod mesh still compiles
    exactly one program per structural group (the pod-scale acceptance
    property the pipeline benchmark gates)."""
    dev_x, dev_y, tx, ty = data
    engine_stats.reset()
    grid = make_grid(_base(local_iters=2, server_iters=2), CH,
                     protocol=("fl", "fd", "mix2fld"), eta=(0.01, 0.02))
    runner = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty,
                         options=ProgramOptions(mesh_shape=(2, 4)))
    runner.run()
    groups = len(grid.program_groups())
    assert engine_stats.programs == groups
    shapes = {p.mesh_shape for _, _, p in runner._programs}
    assert shapes == {(2, 4)}
