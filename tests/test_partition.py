"""Distribution-property tests for the vectorized IID / non-IID device
partitioners (per-device class histograms, cross-device disjointness,
recycling semantics), the Dirichlet severity partitioner, and the
PartitionSpec value objects the sweep engine's partition axes build."""
import jax
import numpy as np
import pytest

from repro.data import (PartitionSpec, partition_dirichlet, partition_iid,
                        partition_noniid, synthetic_images)


@pytest.fixture(scope="module")
def pool():
    x, y = synthetic_images(jax.random.PRNGKey(0), 8000)
    return np.asarray(x), np.asarray(y)


def test_iid_per_device_class_histograms_uniform(pool):
    x, y = pool
    dev_x, dev_y = partition_iid(x, y, 8, 400, 10)
    assert dev_x.shape[:2] == (8, 400)
    for d in range(8):
        counts = np.bincount(dev_y[d], minlength=10)
        assert (counts == 40).all()  # per_device / num_classes each


@pytest.mark.parametrize("num_devices,rare_labels,rare_count,common_count", [
    (10, 2, 2, 62),    # the paper's recipe (|S_d| = 500)
    (6, 3, 4, 30),     # non-default geometry
])
def test_noniid_per_device_class_histograms(pool, num_devices, rare_labels,
                                            rare_count, common_count):
    x, y = pool
    dev_x, dev_y = partition_noniid(
        x, y, num_devices, rare_labels=rare_labels, rare_count=rare_count,
        common_count=common_count)
    per_device = (rare_labels * rare_count
                  + (10 - rare_labels) * common_count)
    assert dev_x.shape[:2] == (num_devices, per_device)
    assert dev_y.shape == (num_devices, per_device)
    for d in range(num_devices):
        counts = np.bincount(dev_y[d], minlength=10)
        assert sorted(counts)[:rare_labels] == [rare_count] * rare_labels
        assert all(c == common_count for c in sorted(counts)[rare_labels:])


def test_noniid_rare_labels_vary_across_devices(pool):
    """The rare pair is drawn per device — over 20 devices the draws must
    not all coincide (probability ~(1/45)^19 under the recipe)."""
    x, y = pool
    _, dev_y = partition_noniid(x, y, 20)
    rare_sets = {tuple(np.flatnonzero(np.bincount(dy, minlength=10) == 2))
                 for dy in dev_y}
    assert len(rare_sets) > 1


def test_noniid_devices_disjoint_while_pool_lasts(pool):
    """With 8000 samples (~800/class) and 10 devices (<= 620/class drawn),
    no sample index may be handed to two devices — devices consume each
    class pool in disjoint slices."""
    x, y = pool
    dev_x, _ = partition_noniid(x, y, 10)
    flat = dev_x.reshape(dev_x.shape[0] * dev_x.shape[1], -1)
    # disjointness up to identical pixel content: hash rows
    uniq = np.unique(flat, axis=0)
    # synthetic images are continuous -> distinct indices have distinct
    # pixels; duplicates would collapse the unique count
    assert uniq.shape[0] == flat.shape[0]


def test_noniid_recycles_when_class_exhausted():
    """A pool smaller than the demand must still fill every device via
    resampling (the recycle branch), keeping the histogram recipe."""
    x, y = synthetic_images(jax.random.PRNGKey(1), 300)  # ~30 per class
    x, y = np.asarray(x), np.asarray(y)
    dev_x, dev_y = partition_noniid(x, y, 4)
    assert dev_x.shape[:2] == (4, 500)
    for d in range(4):
        counts = np.bincount(dev_y[d], minlength=10)
        assert sorted(counts)[:2] == [2, 2]
        assert all(c == 62 for c in sorted(counts)[2:])


def test_iid_determinism_and_seed_sensitivity(pool):
    x, y = pool
    a = partition_iid(x, y, 5, 200, 10, seed=3)[1]
    b = partition_iid(x, y, 5, 200, 10, seed=3)[1]
    c = partition_iid(x, y, 5, 200, 10, seed=4)[1]
    assert (a == b).all()
    assert not (a == c).all()


def test_noniid_determinism_and_seed_sensitivity(pool):
    x, y = pool
    a = partition_noniid(x, y, 5, seed=3)[1]
    b = partition_noniid(x, y, 5, seed=3)[1]
    c = partition_noniid(x, y, 5, seed=4)[1]
    assert (a == b).all()
    assert not (a == c).all()


# ---------------------------------------------------------------------------
# Dirichlet severity partitioner + PartitionSpec value objects
# ---------------------------------------------------------------------------

def _mean_label_entropy(dev_y, num_classes=10):
    ent = []
    for dy in dev_y:
        p = np.bincount(dy, minlength=num_classes) / dy.size
        nz = p[p > 0]
        ent.append(-(nz * np.log(nz)).sum())
    return float(np.mean(ent))


def test_dirichlet_alpha_dials_severity(pool):
    """Small alpha concentrates devices on few labels (low per-device
    label entropy), large alpha approaches the uniform IID histogram."""
    x, y = pool
    _, severe = partition_dirichlet(x, y, 8, 300, 10, alpha=0.05, seed=0)
    _, mild = partition_dirichlet(x, y, 8, 300, 10, alpha=100.0, seed=0)
    assert severe.shape == mild.shape == (8, 300)
    assert _mean_label_entropy(severe) < _mean_label_entropy(mild)
    assert _mean_label_entropy(mild) > 0.9 * np.log(10)  # near-uniform


def test_dirichlet_determinism_and_validation(pool):
    x, y = pool
    a = partition_dirichlet(x, y, 4, 100, 10, alpha=0.5, seed=7)[1]
    b = partition_dirichlet(x, y, 4, 100, 10, alpha=0.5, seed=7)[1]
    c = partition_dirichlet(x, y, 4, 100, 10, alpha=0.5, seed=8)[1]
    assert (a == b).all() and not (a == c).all()
    with pytest.raises(ValueError, match="alpha"):
        partition_dirichlet(x, y, 4, 100, 10, alpha=0.0)


@pytest.mark.parametrize("scheme,n_local", [
    ("iid", 200), ("noniid", 500), ("dirichlet", 120)])
def test_partition_spec_builds_requested_geometry(pool, scheme, n_local):
    x, y = pool
    spec = PartitionSpec(scheme=scheme, n_local=n_local, alpha=0.5, seed=1)
    dev_x, dev_y = spec.build(x, y, 4, 10)
    assert dev_x.shape[:2] == (4, n_local)
    assert dev_y.shape == (4, n_local)


def test_partition_spec_noniid_scales_common_count(pool):
    """noniid n_local != 500 rescales the common-label count (rare pair
    keeps 2 x 2); off-recipe sizes fail loudly."""
    x, y = pool
    _, dev_y = PartitionSpec(scheme="noniid", n_local=60).build(x, y, 4, 10)
    counts = np.bincount(dev_y[0], minlength=10)
    assert sorted(counts)[:2] == [2, 2]
    assert all(c == 7 for c in sorted(counts)[2:])
    with pytest.raises(ValueError, match="noniid n_local"):
        PartitionSpec(scheme="noniid", n_local=61).build(x, y, 4, 10)


def test_partition_spec_validation(pool):
    x, y = pool
    with pytest.raises(ValueError, match="unknown partition scheme"):
        PartitionSpec(scheme="sorted")
    with pytest.raises(ValueError, match="n_local"):
        PartitionSpec(n_local=0)
    with pytest.raises(ValueError, match="alpha"):
        PartitionSpec(alpha=-1.0)
    dev_x, dev_y = PartitionSpec(n_local=100).build(x, y, 4, 10)
    with pytest.raises(ValueError, match="flat sample pool"):
        PartitionSpec(n_local=100).build(dev_x, dev_y, 4, 10)
    # hashable value object: grids group points by spec identity
    assert PartitionSpec(n_local=100) == PartitionSpec(n_local=100)
    assert len({PartitionSpec(seed=0), PartitionSpec(seed=1)}) == 2
