"""Unit tests for losses, per-label output averaging, vocab bucketing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.losses import cross_entropy, fd_loss, kd_regularizer
from repro.core.outputs import (bucket_log_probs, bucketize_tokens,
                                label_averaged_outputs)


def test_cross_entropy_matches_manual():
    logits = jnp.array([[2.0, 0.0, -1.0], [0.5, 0.5, 0.5]])
    labels = jnp.array([0, 2])
    lp = jax.nn.log_softmax(logits)
    want = -(lp[0, 0] + lp[1, 2]) / 2
    np.testing.assert_allclose(float(cross_entropy(logits, labels)),
                               float(want), rtol=1e-6)


def test_cross_entropy_soft_equals_hard_for_onehot():
    logits = jax.random.normal(jax.random.PRNGKey(0), (5, 7))
    labels = jax.random.randint(jax.random.PRNGKey(1), (5,), 0, 7)
    hard = cross_entropy(logits, labels)
    soft = cross_entropy(logits, jax.nn.one_hot(labels, 7))
    np.testing.assert_allclose(float(hard), float(soft), rtol=1e-6)


def test_kd_regularizer_zero_gap_is_entropy():
    """When F == G, psi equals the entropy of G (its minimum over F)."""
    logits = jax.random.normal(jax.random.PRNGKey(2), (4, 6))
    g = jax.nn.softmax(logits)
    psi = kd_regularizer(logits, g)
    ent = -jnp.mean(jnp.sum(g * jnp.log(g), axis=-1))
    np.testing.assert_allclose(float(psi), float(ent), rtol=1e-5)

    # and any other F strictly increases psi
    other = jax.random.normal(jax.random.PRNGKey(3), (4, 6))
    assert float(kd_regularizer(other, g)) > float(psi)


def test_fd_loss_combines():
    logits = jax.random.normal(jax.random.PRNGKey(4), (8, 10))
    labels = jax.random.randint(jax.random.PRNGKey(5), (8,), 0, 10)
    gout = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(6), (10, 10)))
    total, (phi, psi) = fd_loss(logits, labels, gout, beta=0.5)
    np.testing.assert_allclose(float(total), float(phi + 0.5 * psi), rtol=1e-6)
    g = jax.grad(lambda l: fd_loss(l, labels, gout, 0.5)[0])(logits)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_label_averaged_outputs_eq2():
    probs = jnp.array([[0.9, 0.1], [0.2, 0.8], [0.6, 0.4]])
    labels = jnp.array([0, 1, 0])
    favg, cnt = label_averaged_outputs(probs, labels, 2)
    np.testing.assert_allclose(np.asarray(favg[0]), [0.75, 0.25], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(favg[1]), [0.2, 0.8], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(cnt), [2, 1])


def test_bucket_log_probs_normalised():
    for v in (64, 100, 1000):
        logits = jax.random.normal(jax.random.PRNGKey(7), (3, v)) * 3
        blp = bucket_log_probs(logits, 16)
        assert blp.shape == (3, 16)
        np.testing.assert_allclose(np.asarray(jnp.sum(jnp.exp(blp), -1)),
                                   1.0, rtol=1e-5)


def test_bucket_log_probs_consistent_with_token_probs():
    v, nb = 128, 16
    logits = jax.random.normal(jax.random.PRNGKey(8), (v,))
    p = jax.nn.softmax(logits)
    buckets = np.asarray(bucketize_tokens(jnp.arange(v), v, nb))
    want = np.zeros(nb)
    for t in range(v):
        want[buckets[t]] += float(p[t])
    got = np.exp(np.asarray(bucket_log_probs(logits, nb)))
    np.testing.assert_allclose(got, want, rtol=1e-5)
