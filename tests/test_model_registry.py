"""Model/task registry contracts, and the configs-package smoke: every
module under ``src/repro/configs/`` must either back a federated model
registry entry or be explicitly marked serving-only (and then actually
construct + spec its smoke inputs) — no dead config files."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, input_specs, list_archs
from repro.data.pipeline import TaskSpec, parse_task
from repro.models import CNN, MLPClassifier, TransformerClassifier
from repro.models.registry import ModelSpec, build_model, parse_model
from repro.registry import (MODELS, TASKS, canonical_model, canonical_task)

# ---------------------------------------------------------------------------
# Satellite: the configs package has no dead modules.  Each arch either
# constructs through the federated model registry (paper-cnn backs "cnn")
# or is serving-only: it serves through launch.serve / launch.dryrun, so
# its smoke config must build and emit dry-run input specs.
# ---------------------------------------------------------------------------

FEDERATED_BACKED = {"paper-cnn": "cnn"}
SERVING_ONLY = {
    "deepseek-v2-236b", "h2o-danube-3-4b", "mamba2-370m",
    "phi3-mini-3.8b", "qwen2-0.5b", "qwen2-moe-a2.7b", "qwen2-vl-72b",
    "qwen3-14b", "whisper-medium", "zamba2-2.7b",
}


def test_configs_package_has_no_unlisted_modules():
    """Every configs/*.py module registers at least one arch, and every
    registered arch is classified above — adding a config file without
    deciding its serving/federated role fails here."""
    pkg = pathlib.Path(__file__).resolve().parents[1] / "src/repro/configs"
    modules = {p.stem for p in pkg.glob("*.py")} - {"__init__"}
    assert len(modules) == 11  # the ten arch modules + paper_cnn
    assert set(list_archs()) == FEDERATED_BACKED.keys() | SERVING_ONLY


@pytest.mark.parametrize("name", sorted(SERVING_ONLY))
def test_serving_only_config_constructs(name):
    """Serving-only archs build their smoke variant and emit input specs
    for every shape they support (no allocation — ShapeDtypeStructs)."""
    cfg = get_config(name).smoke()
    assert cfg.vocab_size > 0 and cfg.num_layers >= 1
    for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        if not cfg.supports_shape(shape):
            continue
        specs = input_specs(cfg, shape)
        assert specs and all(
            isinstance(s, jax.ShapeDtypeStruct)
            for s in jax.tree.leaves(specs,
                                     is_leaf=lambda x: isinstance(
                                         x, jax.ShapeDtypeStruct)))


@pytest.mark.parametrize("arch,model", sorted(FEDERATED_BACKED.items()))
def test_federated_backed_config_matches_registry(arch, model):
    """paper-cnn's recorded geometry is exactly what the federated
    registry builds for the digits task (12,490 weights)."""
    from repro.configs.paper_cnn import IMAGE_SIZE, NUM_CLASSES
    cfg = get_config(arch)
    task = parse_task("digits")
    assert task.input_shape == (IMAGE_SIZE, IMAGE_SIZE, 1)
    assert cfg.vocab_size == NUM_CLASSES == task.num_classes
    m = build_model(model, task.input_shape, task.num_classes)
    params = m.init(jax.random.PRNGKey(0))
    assert sum(p.size for p in jax.tree.leaves(params)) == 12_490


# ---------------------------------------------------------------------------
# Registry name contracts (same ValueError shape as canonical_protocol)
# ---------------------------------------------------------------------------

def test_canonical_model_and_aliases():
    assert canonical_model("cnn") == "cnn"
    assert canonical_model("conv") == "cnn"
    assert canonical_model("tf") == "transformer"
    with pytest.raises(ValueError, match="unknown model 'resnet'"):
        canonical_model("resnet")


def test_canonical_task_and_aliases():
    assert canonical_task("mnist") == "digits"
    assert canonical_task("cifar10") == "cifar"
    assert canonical_task("speech_commands") == "speech"
    with pytest.raises(ValueError, match="unknown task 'imagenet'"):
        canonical_task("imagenet")


def test_parse_model_composites():
    spec = parse_model("cnn")
    assert isinstance(spec, ModelSpec)
    assert spec.parts == ("cnn",) and not spec.mixed
    mixed = parse_model("cnn+mlp+transformer")
    assert mixed.mixed and mixed.parts == ("cnn", "mlp", "transformer")
    assert mixed.partition(5) == ("cnn", "mlp", "transformer", "cnn",
                                  "mlp")
    # uniform composites collapse to the single architecture
    assert not parse_model("cnn+cnn").mixed
    with pytest.raises(ValueError, match="unknown model 'vgg'"):
        parse_model("cnn+vgg")


def test_task_specs_shape_payload():
    digits = parse_task("digits")
    assert digits.input_shape == (28, 28, 1) and digits.num_classes == 10
    assert digits.sample_bits == 8 * 28 * 28  # the pre-registry default
    cifar = parse_task("cifar")
    assert cifar.input_shape == (32, 32, 3) and cifar.num_classes == 10
    speech = parse_task("speech")
    assert speech.input_shape == (32, 40, 1) and speech.num_classes == 12
    # payload widths respond to the task (latency/link plans see this)
    assert cifar.sample_bits == 8 * 32 * 32 * 3
    assert speech.sample_bits == 16 * 32 * 40
    assert isinstance(digits, TaskSpec)


# ---------------------------------------------------------------------------
# Every model x every task: one shared init/apply contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("task", TASKS)
def test_model_task_cross_product(model, task):
    spec = parse_task(task)
    m = build_model(model, spec.input_shape, spec.num_classes)
    params = m.init(jax.random.PRNGKey(0))
    x, y = spec.data(jax.random.PRNGKey(1), 8)
    logits = m.apply(params, x)
    assert logits.shape == (8, spec.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # differentiable end to end (local SGD runs through jax.grad)
    def loss(p):
        lp = jax.nn.log_softmax(m.apply(p, x))
        return -jnp.mean(lp[jnp.arange(8), y])
    g = jax.grad(loss)(params)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))


@pytest.mark.parametrize("cls,kw", [
    (CNN, {}),
    (MLPClassifier, {}),
    (TransformerClassifier, {}),
])
def test_shape_mismatch_errors_name_both_sides(cls, kw):
    m = cls(num_classes=10, input_shape=(28, 28, 1), **kw)
    params = m.init(jax.random.PRNGKey(0))
    bad = jnp.zeros((2, 32, 32, 3))
    with pytest.raises(ValueError) as ei:
        m.apply(params, bad)
    assert "(28, 28, 1)" in str(ei.value) and "(32, 32, 3)" in str(ei.value)


def test_cnn_derives_geometry_from_input_shape():
    """The satellite bugfix: the conv/fc stack follows the task shape
    instead of the hard-coded 28x28x1."""
    m = CNN(num_classes=10, input_shape=(32, 32, 3))
    params = m.init(jax.random.PRNGKey(0))
    out = m.apply(params, jnp.zeros((2, 32, 32, 3)))
    assert out.shape == (2, 10)
    assert params["conv1"]["w"].shape[2] == 3  # in-channels from the task
    with pytest.raises(ValueError, match="too small"):
        CNN(num_classes=10, input_shape=(2, 2, 1))


# ---------------------------------------------------------------------------
# Serving endpoint takes its batch geometry from the task spec
# ---------------------------------------------------------------------------

def test_inference_endpoint_validates_input_shape():
    from repro.launch.service import InferenceEndpoint
    task = parse_task("cifar")
    m = build_model("mlp", task.input_shape, task.num_classes)
    params = m.init(jax.random.PRNGKey(0))
    ep = InferenceEndpoint(m.apply, batch_size=4,
                           input_shape=task.input_shape)
    with pytest.raises(ValueError) as ei:
        ep.submit(np.zeros((3, 28, 28, 1), np.float32))
    assert "(32, 32, 3)" in str(ei.value) and "(28, 28, 1)" in str(ei.value)
    x, _ = task.data(jax.random.PRNGKey(1), 6)
    ep.submit(x)
    preds = ep.flush(params)
    assert preds.shape == (6,) and ep.batches == 2
