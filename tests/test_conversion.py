"""Output-to-model conversion (eq. 5): PRNG-key regression and the
masked-scan grid path.

The key regression guards the fix for the old silent ``PRNGKey(0)``
default: every caller that omitted ``key`` drew the *identical* batch
sequence — across rounds and across configs — so conversion "randomness"
was a constant.  ``key`` is now a required argument.
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.conversion import output_to_model, output_to_model_steps
from repro.models.cnn import CNN


@pytest.fixture(scope="module")
def setup():
    model = CNN()
    params = model.init(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(3)
    seeds_x = jax.random.normal(k, (40, 28, 28, 1))
    seeds_y = jax.random.randint(jax.random.fold_in(k, 1), (40,), 0, 10)
    gout = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 2),
                                            (10, 10)), -1)
    return model, params, seeds_x, seeds_y, gout


def test_two_keys_give_distinct_batch_draws(setup):
    """Regression: distinct keys must produce distinct batch sequences
    (and so distinct losses and converted params)."""
    model, params, sx, sy, gout = setup
    p1, l1 = output_to_model(model.apply, params, sx, sy, gout, 6, 8,
                             0.05, 0.01, jax.random.PRNGKey(1))
    p2, l2 = output_to_model(model.apply, params, sx, sy, gout, 6, 8,
                             0.05, 0.01, jax.random.PRNGKey(2))
    assert float(np.max(np.abs(np.asarray(l1) - np.asarray(l2)))) > 0
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(diffs)) > 0


def test_same_key_is_deterministic(setup):
    model, params, sx, sy, gout = setup
    p1, l1 = output_to_model(model.apply, params, sx, sy, gout, 6, 8,
                             0.05, 0.01, jax.random.PRNGKey(5))
    p2, l2 = output_to_model(model.apply, params, sx, sy, gout, 6, 8,
                             0.05, 0.01, jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_key_is_required(setup):
    """No silent default: omitting the key must fail loudly."""
    model, params, sx, sy, gout = setup
    sig = inspect.signature(output_to_model)
    assert sig.parameters["key"].default is inspect.Parameter.empty
    with pytest.raises(TypeError):
        output_to_model(model.apply, params, sx, sy, gout, 6, 8,
                        0.05, 0.01)


# ---------------------------------------------------------------------------
# Masked-scan grid path
# ---------------------------------------------------------------------------

def test_masked_steps_equal_static_iters(setup):
    """With host-precomputed step keys, the masked scan at iters < K_max
    is bitwise-equal to the static-iters path at those iters.  Both sides
    run under jit (as in the engine) — eager op-by-op execution may fuse
    differently at the last ulp."""
    import functools
    model, params, sx, sy, gout = setup
    key = jax.random.PRNGKey(7)
    iters, k_max = 5, 9
    ref_p, ref_l = output_to_model(model.apply, params, sx, sy, gout,
                                   iters, 8, 0.05, 0.01, key)
    step_keys = np.zeros((k_max, 2), np.uint32)
    step_keys[:iters] = np.asarray(jax.random.split(key, iters))
    jitted = jax.jit(functools.partial(output_to_model_steps, model.apply),
                     static_argnums=(7,))
    got_p, got_l = jitted(params, sx, sy, gout, jnp.asarray(step_keys),
                          jnp.int32(iters), jnp.int32(sx.shape[0]), 8,
                          0.05, 0.01)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), ref_p, got_p)
    np.testing.assert_array_equal(np.asarray(ref_l),
                                  np.asarray(got_l)[:iters])
    assert float(np.abs(np.asarray(got_l)[iters:]).max()) == 0  # masked


def test_n_train_bound_never_samples_pad_rows(setup):
    """Pad rows are poisoned with NaN; the n_train randint bound must keep
    them out of every batch."""
    model, params, sx, sy, gout = setup
    n_live = 17
    px = np.full((40, 28, 28, 1), np.nan, np.float32)
    px[:n_live] = np.asarray(sx)[:n_live]
    key = jax.random.PRNGKey(11)
    step_keys = jnp.asarray(np.asarray(jax.random.split(key, 6)))
    p, losses = output_to_model_steps(
        model.apply, params, jnp.asarray(px), sy, gout, step_keys,
        jnp.int32(6), jnp.int32(n_live), 8, 0.05, 0.01)
    assert all(np.isfinite(np.asarray(l)) for l in losses)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p))


def test_soft_one_hot_labels_match_hard_labels(setup):
    """Grid promotion of hard labels to one-hot rows (mixed hard/soft
    grids) changes neither the loss nor the converted params."""
    model, params, sx, sy, gout = setup
    key = jax.random.PRNGKey(13)
    p1, l1 = output_to_model(model.apply, params, sx, sy, gout, 6, 8,
                             0.05, 0.01, key)
    soft = jax.nn.one_hot(sy, 10)
    p2, l2 = output_to_model(model.apply, params, sx, soft, gout, 6, 8,
                             0.05, 0.01, key)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-6), p1, p2)
