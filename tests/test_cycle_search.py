"""Segment/sort cycle search vs the DFS reference oracle.

The production path (``find_label_cycles``, method="auto"/"segment") is
the vectorized segment/sort formulation — sort uploads by minor label,
rank-align majors to minors into an injective successor map, extract
disjoint fixed-length windows from the pointer trails.  The budgeted
greedy DFS stays as the small-n reference oracle; these tests pin the
parity contract from both sides:

* small inputs — same validity constraints, and at-least-oracle yield
  (exactly the known maximum on planted graphs);
* budget-exhausting adversarial inputs at n >= 10^4 — the DFS degrades
  to (near) zero, the segment search keeps (most of) the planted yield.
"""
import numpy as np
import pytest

from repro.core.mixup import (find_label_cycles, find_label_cycles_dfs,
                              find_label_cycles_segment)


def _assert_valid(rows, minor, major, dev, length):
    """The shared cycle contract: disjoint rows, cyclic label chain,
    adjacent members from different devices, no degenerate members."""
    flat = rows.reshape(-1)
    assert len(set(flat.tolist())) == flat.size
    for row in rows:
        for k in range(length):
            nxt = row[(k + 1) % length]
            assert major[row[k]] == minor[nxt]
            assert dev[row[k]] != dev[nxt]
        assert not np.any(minor[row] == major[row])


def _random_graph(seed, n=200, C=10, D=20):
    rng = np.random.default_rng(seed)
    minor = rng.integers(0, C, n)
    major = (minor + rng.integers(1, C, n)) % C
    dev = rng.integers(0, D, n)
    return minor, major, dev


# ---------------------------------------------------------------------------
# Small-n parity vs the DFS oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
@pytest.mark.parametrize("length", [3, 4, 5])
def test_small_n_yield_matches_or_beats_dfs_oracle(seed, length):
    """On small inputs the default path must never yield fewer samples
    than the greedy DFS (the auto dispatch keeps the better packing), and
    every row must satisfy the oracle's validity constraints."""
    minor, major, dev = _random_graph(seed)
    ref = find_label_cycles_dfs(minor, major, dev, length)
    got = find_label_cycles(minor, major, dev, length)
    _assert_valid(got, minor, major, dev, length)
    assert len(got) >= len(ref)


@pytest.mark.parametrize("length", [3, 4, 5])
def test_planted_disjoint_cycles_found_exactly(length):
    """Planted disjoint label cycles are the full packing; both searches
    must find exactly all of them (exact yield parity)."""
    reps = 40
    # rep r uses labels r*length .. r*length+length-1 in a cycle, so
    # cycles cannot straddle reps: the max packing is exactly `reps`
    minor = np.concatenate([np.arange(length) + r * length
                            for r in range(reps)])
    major = np.concatenate([(np.arange(length) + 1) % length + r * length
                            for r in range(reps)])
    dev = np.tile(np.arange(length), reps)
    ref = find_label_cycles_dfs(minor, major, dev, length)
    got = find_label_cycles(minor, major, dev, length)
    seg = find_label_cycles_segment(minor, major, dev, length)
    assert len(ref) == len(got) == len(seg) == reps
    _assert_valid(got, minor, major, dev, length)
    _assert_valid(seg, minor, major, dev, length)


def test_dispatch_methods():
    minor, major, dev = _random_graph(7)
    dfs = find_label_cycles(minor, major, dev, 3, method="dfs")
    np.testing.assert_array_equal(
        dfs, find_label_cycles_dfs(minor, major, dev, 3))
    seg = find_label_cycles(minor, major, dev, 3, method="segment")
    _assert_valid(seg, minor, major, dev, 3)
    with pytest.raises(ValueError, match="method"):
        find_label_cycles(minor, major, dev, 3, method="bogus")


def test_segment_empty_and_degenerate_inputs():
    empty = find_label_cycles_segment(np.array([], np.int64),
                                      np.array([], np.int64),
                                      np.array([], np.int64), 3)
    assert empty.shape == (0, 3)
    # single-class uploads: no usable edge at any length
    same = np.full(50, 3)
    for length in (2, 3, 4):
        got = find_label_cycles_segment(same, same, np.arange(50) % 5,
                                        length)
        assert got.shape == (0, length)


# ---------------------------------------------------------------------------
# Degenerate (minor == major) uploads must never sit mid-cycle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["dfs", "segment", "auto"])
def test_degenerate_upload_excluded_mid_cycle(method):
    """Regression: a minor==major upload used to be skipped only as a DFS
    *start* — it could still sit mid-cycle and produce single-class
    "inverse" samples.  The only length-3 closure here routes through the
    degenerate edge 1->1, so the search must return nothing."""
    minor = np.array([0, 1, 1])
    major = np.array([1, 1, 0])  # e0: 0->1, e1: 1->1 (degenerate), e2: 1->0
    dev = np.array([0, 1, 2])
    got = find_label_cycles(minor, major, dev, 3, method=method)
    assert len(got) == 0


@pytest.mark.parametrize("method", ["dfs", "segment"])
def test_degenerate_uploads_never_in_membership_at_scale(method):
    minor, major, dev = _random_graph(11, n=400)
    poison = np.random.default_rng(1).choice(400, 60, replace=False)
    minor = minor.copy()
    minor[poison] = major[poison]  # inject degenerate uploads
    for length in (3, 4):
        rows = find_label_cycles(minor, major, dev, length, method=method)
        assert not np.isin(rows.reshape(-1), poison).any()
        _assert_valid(rows, minor, major, dev, length)


# ---------------------------------------------------------------------------
# Budget-exhausting adversarial graph at n >= 10^4
# ---------------------------------------------------------------------------

def _adversarial_graph(n_ladder=9000, n_planted=500, seed=0):
    """Ladder edges l -> l+1 can never close a cycle (no wrap edges), but
    they dominate the index order and the branching, so the greedy DFS
    exhausts its step budget before reaching the planted 3-cycles at the
    end of the index space.  Only planted edges (label jumps 0->4->8->0)
    can appear in any 3-cycle, so the max packing is exactly
    ``n_planted``."""
    rng = np.random.default_rng(seed)
    lm = rng.integers(0, 11, n_ladder)
    ladder = np.stack([lm, lm + 1], 1)
    planted = np.tile(np.array([[0, 4], [4, 8], [8, 0]]), (n_planted, 1))
    edges = np.concatenate([ladder, planted])
    dev = np.concatenate([rng.integers(0, 50, n_ladder),
                          np.tile([0, 1, 2], n_planted)])
    return edges[:, 0], edges[:, 1], dev, n_planted


def test_adversarial_graph_segment_beats_budgeted_dfs():
    """The acceptance contract of the tentpole: at n >= 10^4 on a graph
    built to exhaust the DFS step budget, the segment/sort search keeps
    the planted yield while the DFS degrades toward zero."""
    minor, major, dev, n_planted = _adversarial_graph()
    assert minor.shape[0] >= 10_000
    ref = find_label_cycles_dfs(minor, major, dev, 3)  # default budget
    got = find_label_cycles(minor, major, dev, 3)      # auto -> segment
    _assert_valid(got, minor, major, dev, 3)
    assert len(got) >= len(ref)
    assert len(got) >= n_planted // 2  # most of the planted packing
    assert len(ref) < n_planted // 10  # the DFS really did degrade


def test_adversarial_graph_segment_is_fast():
    """No step budget does not mean unbounded time: the sweep loop is
    O(n log n) per matching and must stay interactive at 10^4+ uploads."""
    import time
    minor, major, dev, _ = _adversarial_graph()
    t0 = time.perf_counter()
    find_label_cycles_segment(minor, major, dev, 3)
    assert time.perf_counter() - t0 < 30
