"""Continuous-serving driver: crash-safe resume, churn, stragglers,
outage-convergence gating, and the batched inference endpoint.

Golden-sized configs (D=4, 8 local iters) keep the file in the fast
tier; the acceptance property — a killed fixed-seed service resumes from
the latest checkpoint and reproduces the uninterrupted run's tail
bit-identically — is locked down here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.channel import ChannelConfig
from repro.core.protocols import FederatedConfig, FederatedTrainer
from repro.data import partition_iid, synthetic_images
from repro.launch.service import (ChurnConfig, FederatedService,
                                  InferenceEndpoint)
from repro.models.cnn import CNN


@pytest.fixture(scope="module")
def data():
    x, y = synthetic_images(jax.random.PRNGKey(42), 1400)
    dev_x, dev_y = partition_iid(np.asarray(x[:1200]), np.asarray(y[:1200]),
                                 4, 300, 10, seed=0)
    return dev_x, dev_y, jnp.asarray(x[1200:]), jnp.asarray(y[1200:])


def _cfg(protocol="fd", **kw):
    base = dict(protocol=protocol, num_devices=4, local_iters=8,
                local_batch=16, server_iters=8, server_batch=16,
                max_rounds=3, n_seed=6, n_inverse=12, seed=0)
    base.update(kw)
    return FederatedConfig(**base)


CH = ChannelConfig(num_devices=4, p_up_dbm=40.0)
# churn + straggler regime for the robustness tests
CH_STRAG = ChannelConfig(num_devices=4, p_up_dbm=40.0,
                         compute_mean_s=0.05, deadline_s=0.08)
CHURN = ChurnConfig(p_active=0.6, min_active=2, seed=3)


def _svc(data, protocol="fd", ch=CH, churn=None, tmp=None, **kw):
    dev_x, dev_y, tx, ty = data
    svc = FederatedService(CNN(), _cfg(protocol), ch, churn=churn,
                           ckpt_dir=str(tmp) if tmp else None, **kw)
    return svc.bind_data(dev_x, dev_y, tx, ty)


def _tail(records):
    keys = ("round", "acc", "loss", "round_latency_s", "uplink_ok",
            "n_active")
    return [{k: r[k] for k in keys} for r in records]


# ---- equivalence with the terminate-and-exit loop ------------------------


def test_service_without_churn_matches_trainer_run(data):
    """Churn/stragglers off: the service's records are run()'s history
    bit-for-bit (same PRNG stream through the factored step)."""
    dev_x, dev_y, tx, ty = data
    h = FederatedTrainer(CNN(), _cfg("fd"), CH).run(dev_x, dev_y, tx, ty)
    svc = _svc(data, "fd")
    recs = svc.run_rounds(3)
    assert [r["acc"] for r in recs] == h["acc"]
    assert [r["loss"] for r in recs] == h["loss"]
    assert [r["round_latency_s"] for r in recs] == h["round_latency_s"]
    assert [r["uplink_ok"] for r in recs] == h["uplink_ok"]
    assert all(r["n_active"] == 4 for r in recs)
    assert svc.state["converged_round"] == h["converged_round"]


# ---- the acceptance property: kill mid-training, resume, identical tail --


@pytest.mark.parametrize("protocol", ["fd", "mix2fld"])
def test_killed_service_resumes_bit_identically(protocol, data, tmp_path):
    """Fixed-seed service under churn + straggler timeouts, checkpointing
    every round: a fresh process restoring the round-2 checkpoint must
    reproduce the uninterrupted run's remaining rounds exactly —
    including the PRNG key bits — with the mix2fld case also exercising
    the round-1 seed set through the checkpoint."""
    svc = _svc(data, protocol, ch=CH_STRAG, churn=CHURN,
               tmp=tmp_path / "ck", ckpt_every=1)
    recs = svc.run_rounds(4)
    assert len({r["n_active"] for r in recs}) > 1  # churn really resized

    svc2 = _svc(data, protocol, ch=CH_STRAG, churn=CHURN,
                tmp=tmp_path / "ck")
    assert svc2.restore(step=2) == 2
    np.testing.assert_array_equal(np.asarray(svc2.state["key"]),
                                  np.asarray(svc.state["key"]))
    tail = svc2.run_rounds(2)
    assert _tail(tail) == _tail(recs[2:])
    for a, b in zip(jax.tree.leaves(svc.state["g_params"]),
                    jax.tree.leaves(svc2.state["g_params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)
    assert svc2.state["converged_round"] == svc.state["converged_round"]
    # the resumed history is the full run's (prefix from the manifest
    # meta, tail recomputed)
    assert _tail(svc2.history) == _tail(svc.history)


def test_crash_mid_save_resumes_from_last_good_checkpoint(data, tmp_path,
                                                          monkeypatch):
    """Exception injection mid-save (the SIGKILL stand-in): the torn
    round-2 checkpoint must not exist, and a fresh service restores
    round 1 and reproduces rounds 2..3 of an uninterrupted run."""
    d = tmp_path / "ck"
    ref = _svc(data, "fd", ch=CH_STRAG, churn=CHURN, tmp=tmp_path / "ref",
               ckpt_every=1)
    ref_recs = ref.run_rounds(3)

    svc = _svc(data, "fd", ch=CH_STRAG, churn=CHURN, tmp=d, ckpt_every=1)
    svc.run_rounds(1)
    real_savez = np.savez

    def boom(*a, **k):
        raise RuntimeError("killed mid-save")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(RuntimeError, match="killed mid-save"):
        svc.run_rounds(1)  # round 2 trains, then dies checkpointing
    monkeypatch.setattr(np, "savez", real_savez)

    assert ckpt.latest_step(str(d)) == 1
    svc2 = _svc(data, "fd", ch=CH_STRAG, churn=CHURN, tmp=d, ckpt_every=1)
    assert svc2.restore() == 1
    tail = svc2.run_rounds(2)
    assert _tail(tail) == _tail(ref_recs[1:])


# ---- outage / churn / straggler semantics --------------------------------


def test_service_total_outage_round_records_no_convergence(data):
    svc = _svc(data, "fd", ch=ChannelConfig(num_devices=4, theta=1e9))
    svc.trainer.fc.eps = 10.0  # any rel passes — only the gate protects
    recs = svc.run_rounds(3)
    assert [r["uplink_ok"] for r in recs] == [0, 0, 0]
    assert svc.state["converged_round"] is None


def test_straggler_timeouts_shrink_aggregation_set(data):
    """An aggressive deadline drops devices from up_ok and charges the
    waiting time; the record reports how many straggled."""
    svc = _svc(data, "fd",
               ch=ChannelConfig(num_devices=4, p_up_dbm=40.0,
                                compute_mean_s=1.0, deadline_s=0.7))
    recs = svc.run_rounds(3)
    assert sum(r["n_straggle"] for r in recs) > 0
    for r in recs:
        assert r["uplink_ok"] <= 4 - r["n_straggle"]


def test_churn_draw_is_stateless_and_respects_min_active():
    churn = ChurnConfig(p_active=0.3, min_active=2, seed=5)
    for p in range(1, 30):
        a = churn.active_devices(0, p, 6)
        b = churn.active_devices(0, p, 6)
        np.testing.assert_array_equal(a, b)  # pure function of (seed, p)
        assert len(a) >= 2
        assert len(np.unique(a)) == len(a)
        assert a.min() >= 0 and a.max() < 6
    # different rounds actually draw different cohorts
    draws = {tuple(churn.active_devices(0, p, 6)) for p in range(1, 30)}
    assert len(draws) > 1


def test_churn_config_validation():
    with pytest.raises(ValueError, match="p_active"):
        ChurnConfig(p_active=0.0)
    with pytest.raises(ValueError, match="min_active"):
        ChurnConfig(min_active=0)


def test_churned_cohort_state_scatters_back_to_pool(data):
    """Only active devices' pool state changes in a churned round."""
    svc = _svc(data, "fd", churn=ChurnConfig(p_active=0.5, min_active=2,
                                             seed=1))
    before = np.asarray(svc.state["dev_gout"]).copy()
    rec = svc.run_rounds(2)[-1]  # round 2: gout has left the prior
    after = np.asarray(svc.state["dev_gout"])
    active = set(rec["active"].tolist())
    assert 0 < len(active) < 4
    # previously-active devices may already differ from init; compare
    # against the state snapshot, which run_rounds(2) evolved twice
    changed = {d for d in range(4)
               if not np.array_equal(before[d], after[d])}
    assert changed  # somebody trained
    assert changed <= active | set(
        svc.history[0]["active"].tolist())


# ---- inference endpoint --------------------------------------------------


def test_endpoint_pads_to_fixed_batch_and_matches_direct_apply(data):
    dev_x, dev_y, tx, ty = data
    svc = _svc(data, "fd", serve_batch=8)
    svc.run_rounds(1)
    x = np.asarray(tx[:13])  # not a multiple of the batch size
    preds = svc.serve(x)
    assert preds.shape == (13,)
    want = np.argmax(np.asarray(CNN().apply(svc.state["g_params"],
                                            jnp.asarray(x))), axis=-1)
    np.testing.assert_array_equal(preds, want)
    assert svc.endpoint.served == 13
    assert svc.endpoint.batches == 2  # 8 + padded 5
    assert svc.endpoint.pending == 0


def test_endpoint_flush_empty_queue_is_noop(data):
    svc = _svc(data, "fd")
    out = svc.endpoint.flush(svc.state["g_params"])
    assert out.shape == (0,)


def test_endpoint_is_separate_from_training_state(data):
    """Serving between rounds must not perturb training: records with
    and without interleaved serving are identical."""
    dev_x, dev_y, tx, ty = data
    a = _svc(data, "fd")
    recs_a = []
    for _ in range(2):
        recs_a.append(a.step())
        a.serve(np.asarray(tx[:4]))
    b = _svc(data, "fd")
    recs_b = b.run_rounds(2)
    assert _tail(recs_a) == _tail(recs_b)


def test_service_requires_bound_data(data):
    svc = FederatedService(CNN(), _cfg("fd"), CH)
    with pytest.raises(RuntimeError, match="bind_data"):
        svc.step()


def test_bind_data_validates_pool_size(data):
    dev_x, dev_y, tx, ty = data
    svc = FederatedService(CNN(), _cfg("fd", num_devices=7), CH)
    with pytest.raises(ValueError, match="num_devices=7"):
        svc.bind_data(dev_x, dev_y, tx, ty)


# ---- churn stream stability at p_active >= 1 -----------------------------


def test_full_participation_churn_consumes_the_same_stream():
    """p_active=1.0 must return the whole pool AND draw the same
    uniforms a fractional p_active would — regression for the branch
    that skipped the rng entirely, which made p_active=1.0 histories
    diverge from p_active=1-eps ones through later draws."""
    full = ChurnConfig(p_active=1.0, min_active=1, seed=5)
    near = ChurnConfig(p_active=1.0 - 1e-9, min_active=1, seed=5)
    for p in range(1, 10):
        a = full.active_devices(0, p, 6)
        np.testing.assert_array_equal(a, np.arange(6))
        np.testing.assert_array_equal(a, near.active_devices(0, p, 6))


def test_churn_and_sampler_streams_compose_without_bias():
    """Churn and client sampling at identical (default) seeds must draw
    from disjoint streams.  When they shared one stream, the sampler's
    uniforms over the churned cohort were exactly the first len(cohort)
    values churn had already thresholded below p_active, so aligned
    low-index survivors were selected ~99% of the time instead of ~q.
    Here the conditional selection rate P(sampled | churn-active) must
    sit near q for low-index devices too."""
    from repro.core.sampling import SamplerConfig

    pool, rounds = 400, 200
    churn = ChurnConfig(p_active=0.5, min_active=1, seed=0)
    sampler = SamplerConfig(sample_ratio=0.5, min_active=1, seed=0)
    active = np.zeros(pool, np.int64)
    chosen = np.zeros(pool, np.int64)
    for p in range(1, rounds + 1):
        idx = churn.active_devices(0, p, pool)
        sub = sampler.cohort(0, p, len(idx))
        active[idx] += 1
        chosen[idx[sub]] += 1
    rate = chosen / np.maximum(active, 1)
    # the historical bias: the first ~half of the pool selected at ~0.99
    lo = rate[:100].mean()
    assert 0.4 < lo < 0.6, f"low-index selection rate {lo:.3f}"
    assert 0.4 < rate.mean() < 0.6
    assert rate.max() < 0.8  # no device is near-deterministically picked


# ---- flush failure re-queues the whole failed batch ----------------------


def test_flush_requeues_everything_when_predict_fails_mid_loop(data):
    """Inject a predict that dies on its second batch: since the
    exception propagates, NO result reached the caller — so every
    request of the failed flush must stay queued (including the chunks
    that predicted before the crash; re-queueing only the unreached
    tail silently lost them).  The retry answers all of them."""
    svc = _svc(data, "fd", serve_batch=4)
    ep = svc.endpoint
    real = ep._predict
    calls = {"n": 0}

    def flaky(params, x):
        calls["n"] += 1
        if calls["n"] == 2:
            raise RuntimeError("backend died")
        return real(params, x)

    ep._predict = flaky
    dev_x, dev_y, tx, ty = data
    ep.submit(np.asarray(tx[:10]))  # 3 batches: 4 + 4 + padded 2
    with pytest.raises(RuntimeError, match="backend died"):
        ep.flush(svc.state["g_params"])
    assert ep.pending == 10  # the whole flush is re-queued
    assert ep.served == 0    # nothing reached the caller
    ep._predict = real
    preds = ep.flush(svc.state["g_params"])
    assert preds.shape == (10,)
    want = np.argmax(np.asarray(CNN().apply(svc.state["g_params"],
                                            jnp.asarray(tx[:10]))),
                     axis=-1)
    np.testing.assert_array_equal(preds, want)
    assert ep.pending == 0
    assert ep.served == 10


def test_flush_requeues_everything_when_apply_fn_fails_at_trace(data):
    """A broken apply_fn raises inside jit tracing on the FIRST chunk:
    the whole queue must survive the failed flush."""

    def bad_apply(params, x):
        raise ValueError("no such model")

    ep = InferenceEndpoint(bad_apply, batch_size=4)
    dev_x, dev_y, tx, ty = data
    ep.submit(np.asarray(tx[:7]))
    svc = _svc(data, "fd")
    with pytest.raises(ValueError, match="no such model"):
        ep.flush(svc.state["g_params"])
    assert ep.pending == 7
    assert ep.served == 0 and ep.batches == 0


def test_flush_requeue_keeps_submission_order(data):
    """Requests submitted after a failed flush serve AFTER the re-queued
    tail."""
    svc = _svc(data, "fd", serve_batch=2)
    ep = svc.endpoint
    real = ep._predict
    ep._predict = lambda *a: (_ for _ in ()).throw(RuntimeError("x"))
    dev_x, dev_y, tx, ty = data
    ep.submit(np.asarray(tx[:3]))
    with pytest.raises(RuntimeError):
        ep.flush(svc.state["g_params"])
    ep.submit(np.asarray(tx[3:5]))
    ep._predict = real
    preds = ep.flush(svc.state["g_params"])
    want = np.argmax(np.asarray(CNN().apply(svc.state["g_params"],
                                            jnp.asarray(tx[:5]))), axis=-1)
    np.testing.assert_array_equal(preds, want)


# ---- participation-correct DP accounting through the service -------------


def test_service_dp_epsilon_composes_over_participation_only(data):
    """Regression for the all-rounds DP over-report: under 50% churn the
    busiest device of this seed joins 4 of 6 rounds, so its epsilon must
    compose over 4 — strictly below the global all-rounds epsilon."""
    dev_x, dev_y, tx, ty = data
    churn = ChurnConfig(p_active=0.5, min_active=1, seed=3)
    svc = FederatedService(CNN(), _cfg("fd", codec="dp_gaussian",
                                       dp_sigma=2.0, max_rounds=6),
                           CH, churn=churn)
    svc.bind_data(dev_x, dev_y, tx, ty)
    recs = svc.run_rounds(6)
    acct = svc._acct
    assert acct is not None and acct.rounds == 6
    counts = np.zeros(4, np.int64)
    for r in recs:
        counts[r["active"]] += 1
    assert dict(acct.device_rounds) == {
        int(d): int(c) for d, c in enumerate(counts) if c}
    assert acct.device_rounds_max() == counts.max() < 6
    assert acct.epsilon_device_max() < acct.epsilon()
    assert recs[-1]["dp_epsilon_device_max"] == acct.epsilon_device_max()
    assert acct.ledger()["sample_ratio"] == pytest.approx(0.5)


def test_checkpoint_roundtrips_device_participation(data, tmp_path):
    """device_rounds must survive save/restore — a resumed service keeps
    composing per-device epsilon from the true participation history."""
    dev_x, dev_y, tx, ty = data
    churn = ChurnConfig(p_active=0.5, min_active=1, seed=3)
    fc = _cfg("fd", codec="dp_gaussian", dp_sigma=2.0, max_rounds=6)
    svc = FederatedService(CNN(), fc, CH, churn=churn,
                           ckpt_dir=str(tmp_path / "ck"), ckpt_every=1)
    svc.bind_data(dev_x, dev_y, tx, ty)
    svc.run_rounds(3)
    svc2 = FederatedService(CNN(), fc, CH, churn=churn,
                            ckpt_dir=str(tmp_path / "ck"))
    svc2.bind_data(dev_x, dev_y, tx, ty)
    assert svc2.restore() == 3
    assert svc2._acct.device_rounds == svc._acct.device_rounds
    assert svc2._acct.rounds == 3
    svc.run_rounds(3)
    svc2.run_rounds(3)
    assert svc2._acct.device_rounds == svc._acct.device_rounds
    assert svc2._acct.epsilon_device_max() == \
        svc._acct.epsilon_device_max()
