"""Launch-layer step tests on CPU (1-device mesh, smoke configs):
train/prefill/decode jit + the multi-pod federated sync steps."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import (make_decode_step, make_favg_step,
                                make_fd_sync_step, make_fl_sync_step,
                                make_local_train_step, make_prefill_step,
                                make_train_step)
from repro.models import kvcache
from repro.models.transformer import Transformer, init_params


def _cfg(arch="qwen2-0.5b", **kw):
    return dataclasses.replace(get_config(arch).smoke(), **kw)


def test_prefill_then_decode_steps_consistent():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)
    prefill = jax.jit(make_prefill_step(cfg, S + 1))
    decode = jax.jit(make_decode_step(cfg))
    logits_last, cache = prefill(params, {"tokens": toks[:, :S]})
    assert logits_last.shape == (B, cfg.vocab_size)
    nxt, cache2 = decode(params, {"tokens": toks[:, S:S + 1],
                                  "cache": cache})
    assert nxt.shape == (B,)
    assert int(cache2["pos"]) == S + 1
    # greedy next token from prefill logits == decode applied at position S?
    # (decode consumes the TRUE token; just check decode output is finite
    # and cache advanced)
    m = Transformer(cfg)
    full, _, _ = m.apply(params, {"tokens": toks[:, :S]})
    np.testing.assert_allclose(np.asarray(logits_last),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_grad_accum_matches_single_batch():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab_size)}
    s1 = jax.jit(make_train_step(cfg, grad_accum=1))
    s2 = jax.jit(make_train_step(cfg, grad_accum=2))
    p1, m1 = s1(params, batch)
    p2, m2 = s2(params, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_fd_sync_step_converts_and_broadcasts():
    cfg = _cfg()
    n_pods = 2
    params = init_params(cfg, jax.random.PRNGKey(0))
    pod_in = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (n_pods,) + p.shape), params)
    favg = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2),
                                            (n_pods, cfg.fd_buckets,
                                             cfg.fd_buckets)), axis=-1)
    seed_batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3),
                                               (4, 32), 0, cfg.vocab_size)}
    fd_sync = jax.jit(make_fd_sync_step(cfg, n_pods, ks_iters=2))
    pod_params, gout = fd_sync(pod_in, favg, seed_batch)
    np.testing.assert_allclose(np.asarray(gout),
                               np.asarray(jnp.mean(favg, 0)), rtol=1e-6)
    for leaf in jax.tree.leaves(pod_params):
        assert leaf.shape[0] == n_pods
        np.testing.assert_allclose(np.asarray(leaf[0], np.float32),
                                   np.asarray(leaf[1], np.float32))
    # conversion actually moved the weights
    moved = any(
        not np.allclose(np.asarray(a[0], np.float32),
                        np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(pod_params), jax.tree.leaves(params)))
    assert moved


def test_fl_sync_step_averages_pods():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    pod_params = jax.tree.map(
        lambda p: jnp.stack([p, 3.0 * p.astype(jnp.float32)]).astype(p.dtype),
        params)
    fl_sync = jax.jit(make_fl_sync_step(cfg, 2))
    out = fl_sync(pod_params)
    for o, p in zip(jax.tree.leaves(out), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(o[0], np.float32),
                                   np.asarray(2.0 * p, np.float32),
                                   rtol=2e-2, atol=1e-4)
        np.testing.assert_allclose(np.asarray(o[0], np.float32),
                                   np.asarray(o[1], np.float32))


def test_local_train_step_keeps_pods_independent():
    cfg = _cfg()
    n_pods = 2
    params = init_params(cfg, jax.random.PRNGKey(0))
    pod_params = jax.tree.map(
        lambda p: jnp.broadcast_to(p, (n_pods,) + p.shape), params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (n_pods, 4, 32), 0,
                              cfg.vocab_size)
    step = jax.jit(make_local_train_step(cfg, n_pods))
    new_pp, metrics = step(pod_params, {"tokens": toks})
    # different pod data => different pod params after the local step
    diff = any(
        not np.allclose(np.asarray(l[0], np.float32),
                        np.asarray(l[1], np.float32))
        for l in jax.tree.leaves(new_pp))
    assert diff
    assert metrics["loss"].shape == (n_pods,)


def test_favg_step_rows_are_distributions():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    favg = jax.jit(make_favg_step(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                              cfg.vocab_size)
    table = favg(params, {"tokens": toks})
    assert table.shape == (cfg.fd_buckets, cfg.fd_buckets)
    sums = np.asarray(table.sum(-1))
    nz = sums > 0
    np.testing.assert_allclose(sums[nz], 1.0, atol=1e-4)


def test_cache_specs_match_init_cache():
    for arch in ("qwen2-0.5b", "mamba2-370m", "zamba2-2.7b",
                 "whisper-medium", "deepseek-v2-236b"):
        cfg = get_config(arch).smoke()
        specs = kvcache.cache_specs(cfg, 2, 64)
        cache = kvcache.init_cache(cfg, 2, 64)
        s_flat = jax.tree.leaves(specs)
        c_flat = jax.tree.leaves(cache)
        assert len(s_flat) == len(c_flat)
        for s, c in zip(s_flat, c_flat):
            assert tuple(s.shape) == tuple(c.shape)
            assert s.dtype == c.dtype
