"""Sweep-vs-loop equivalence: the compiled grid engine must reproduce
per-point ``FederatedTrainer.run`` histories bitwise-or-1e-6, on both the
vmapped and the ``shard_devices`` round-loop paths.

Configs are golden-sized (D=4, 8 local iters, 3 rounds) so the whole file
stays in the fast tier.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.channel import ChannelConfig
from repro.core.protocols import PROTOCOLS, FederatedConfig
from repro.data import PartitionSpec, partition_iid, synthetic_images
from repro.models.cnn import CNN
from repro.sweep import (CH_SWEEPABLE, FED_SWEEPABLE, PART_SWEEPABLE,
                         SweepRunner, engine_stats, make_grid,
                         make_task_data, run_pointwise, run_sweep)

CH = ChannelConfig(num_devices=4, p_up_dbm=40.0)


@pytest.fixture(scope="module")
def data():
    x, y = synthetic_images(jax.random.PRNGKey(42), 1400)
    dev_x, dev_y = partition_iid(np.asarray(x[:1200]), np.asarray(y[:1200]),
                                 4, 300, 10, seed=0)
    return dev_x, dev_y, jnp.asarray(x[1200:]), jnp.asarray(y[1200:])


@pytest.fixture(scope="module")
def pool():
    """Flat sample pool for partitioned grids (each point's PartitionSpec
    splits it)."""
    x, y = synthetic_images(jax.random.PRNGKey(42), 1400)
    return (np.asarray(x[:1200]), np.asarray(y[:1200]),
            jnp.asarray(x[1200:]), jnp.asarray(y[1200:]))


def _base(**kw):
    cfg = dict(protocol="mix2fld", num_devices=4, local_iters=8,
               local_batch=16, server_iters=8, server_batch=16,
               max_rounds=3, n_seed=6, n_inverse=12, seed=0)
    cfg.update(kw)
    return FederatedConfig(**cfg)


def _assert_equivalent(result, histories):
    for g, h in enumerate(histories):
        sh = result.history(g)
        np.testing.assert_allclose(sh["acc"], h["acc"], atol=1e-6,
                                   err_msg=f"acc, point {g}")
        np.testing.assert_allclose(sh["loss"], h["loss"], atol=1e-6,
                                   err_msg=f"loss, point {g}")
        np.testing.assert_allclose(sh["round_latency_s"],
                                   h["round_latency_s"], rtol=1e-6,
                                   err_msg=f"latency, point {g}")
        assert sh["uplink_ok"] == h["uplink_ok"], f"uplink_ok, point {g}"
        assert sh["converged_round"] == h["converged_round"], \
            f"converged_round, point {g}"


# ---------------------------------------------------------------------------
# The headline equivalence: a 2x3 grid with ragged conversion budgets
# (exercises the per-config iteration masking) on both round-loop paths
# ---------------------------------------------------------------------------

def test_sweep_matches_loop_2x3_vmapped(data):
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(), CH, eta=(0.01, 0.02),
                     server_iters=(6, 8, 12))
    assert grid.shape == (2, 3) and grid.size == 6
    res = run_sweep(CNN(), grid, dev_x, dev_y, tx, ty)
    _assert_equivalent(res, run_pointwise(CNN(), grid, dev_x, dev_y, tx, ty))


def test_sweep_matches_loop_2x3_sharded(data):
    """shard_devices grids place the device axis on the "data" mesh under
    the grid vmap; on this host's mesh the history must still equal the
    per-point (sharded) loop."""
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(shard_devices=True), CH, eta=(0.01, 0.02),
                     server_iters=(6, 8, 12))
    runner = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty)
    assert runner.mesh is not None
    res = runner.run()
    _assert_equivalent(res, run_pointwise(CNN(), grid, dev_x, dev_y, tx, ty))


@pytest.mark.multichip
def test_sweep_sharded_multichip_uses_multiple_shards(data):
    """Pod validation: with >1 chip the sweep's device mesh must actually
    split the population and still reproduce the vmapped sweep."""
    dev_x, dev_y, tx, ty = data
    grid_s = make_grid(_base(shard_devices=True), CH, eta=(0.01, 0.02))
    runner = SweepRunner(CNN(), grid_s, dev_x, dev_y, tx, ty)
    assert runner.mesh.devices.size > 1
    res_s = runner.run()
    grid_v = make_grid(_base(), CH, eta=(0.01, 0.02))
    res_v = run_sweep(CNN(), grid_v, dev_x, dev_y, tx, ty)
    np.testing.assert_allclose(res_s.acc, res_v.acc, atol=1e-4)
    np.testing.assert_allclose(res_s.loss, res_v.loss, atol=1e-4)


# ---------------------------------------------------------------------------
# Every protocol branch of the grid round step
# ---------------------------------------------------------------------------

def test_sweep_matches_loop_fl_channel_axis(data):
    """Channel axes batch the SNR/outage draws: both regimes of a
    ``p_up_dbm`` axis must reproduce their per-point loop runs, and the
    regimes must actually differ (the low-power point pays more uplink
    slots; at D=4 the FL payload still fits the window, unlike the
    paper's D=10 boundary)."""
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(protocol="fl"), CH, p_up_dbm=(23.0, 40.0))
    res = run_sweep(CNN(), grid, dev_x, dev_y, tx, ty)
    hs = run_pointwise(CNN(), grid, dev_x, dev_y, tx, ty)
    _assert_equivalent(res, hs)
    assert res.history(0)["round_latency_s"] != res.history(1)[
        "round_latency_s"]  # the two channel regimes drew differently


def test_sweep_matches_loop_fd(data):
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(protocol="fd"), CH, beta=(0.005, 0.02))
    res = run_sweep(CNN(), grid, dev_x, dev_y, tx, ty)
    _assert_equivalent(res, run_pointwise(CNN(), grid, dev_x, dev_y, tx, ty))


def test_sweep_total_outage_never_converges_and_matches_loop(data):
    """Regression for the spurious-convergence bug on the grid path: a
    theta axis spanning a workable SNR target and an unreachable one
    (every link outages every round) must (a) stay loop-equivalent and
    (b) record no converged_round at the outage point even with an eps
    that any rel passes — the frozen global state is not convergence."""
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(protocol="fd", eps=10.0), CH,
                     theta=(3.0, 1e9))
    res = run_sweep(CNN(), grid, dev_x, dev_y, tx, ty)
    _assert_equivalent(res, run_pointwise(CNN(), grid, dev_x, dev_y, tx, ty))
    h_ok, h_out = res.history(0), res.history(1)
    assert all(n > 0 for n in h_ok["uplink_ok"])
    assert h_ok["converged_round"] == 2
    assert h_out["uplink_ok"] == [0, 0, 0]
    assert h_out["converged_round"] is None


@pytest.mark.parametrize("protocol,axes", [
    ("fld", dict(n_seed=(4, 6))),
    ("mixfld", dict(lam=(0.1, 0.3))),
])
def test_sweep_matches_loop_fld_family(data, protocol, axes):
    """Ragged seed budgets (padded train sets + n_train masking) and soft
    MixFLD labels both reproduce the loop."""
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(protocol=protocol), CH, **axes)
    res = run_sweep(CNN(), grid, dev_x, dev_y, tx, ty)
    _assert_equivalent(res, run_pointwise(CNN(), grid, dev_x, dev_y, tx, ty))


def test_sweep_warm_rerun_is_deterministic(data):
    """A second run() of the same runner reuses the compiled program and
    returns the identical histories."""
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(), CH, eta=(0.01, 0.02))
    runner = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty)
    r1, r2 = runner.run(), runner.run()
    np.testing.assert_array_equal(r1.acc, r2.acc)
    np.testing.assert_array_equal(r1.loss, r2.loss)
    # (warm-call speedup itself is measured by bench_seed_sweep, not
    # asserted here — wall-clock ordering would flake on loaded CI)


# ---------------------------------------------------------------------------
# Memoized host seed prep: grids that do not vary seed-determining fields
# collect seeds exactly once (counter-instrumented) and still reproduce
# the per-point loop
# ---------------------------------------------------------------------------

def test_eta_only_grid_preps_seeds_exactly_once(data):
    """eta does not determine the round-1 seed sets, so a G=3 eta grid is
    one seed group: host prep must run once, the other two points must be
    memo hits, and the sweep must still match the per-point loop
    histories within 1e-6."""
    from repro.core.seed_prep import prep_stats
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(), CH, eta=(0.01, 0.02, 0.03))
    assert len(grid.seed_groups()) == 1
    prep_stats.reset()
    runner = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty)
    assert prep_stats.runs == 1  # host prep ran exactly once for G=3
    assert runner.seed_prep_stats == {
        "groups": 1, "prep_runs": 1, "memo_hits": 2}
    res = runner.run()
    _assert_equivalent(res, run_pointwise(CNN(), grid, dev_x, dev_y, tx, ty))


def test_seed_axis_grid_preps_once_per_group(data):
    """A (n_seed x eta) grid has one seed group per n_seed value; the
    eta replicas inside each group are memo hits sharing one prep result
    object."""
    from repro.core.seed_prep import prep_stats
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(), CH, n_seed=(4, 6), eta=(0.01, 0.02))
    groups = grid.seed_groups()
    assert len(groups) == 2 and all(len(g) == 2 for g in groups.values())
    prep_stats.reset()
    runner = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty)
    assert prep_stats.runs == 2
    assert runner.seed_prep_stats == {
        "groups": 2, "prep_runs": 2, "memo_hits": 2}
    # C-order points: (ns4, eta.01), (ns4, eta.02), (ns6, ...), (ns6, ...)
    assert runner.seed_sets[0] is runner.seed_sets[1]
    assert runner.seed_sets[2] is runner.seed_sets[3]
    assert runner.seed_sets[0] is not runner.seed_sets[2]
    res = runner.run()
    _assert_equivalent(res, run_pointwise(CNN(), grid, dev_x, dev_y, tx, ty))


def test_channel_only_grid_preps_seeds_exactly_once(data):
    """Channel fields never touch the seed sets: a p_up_dbm axis on an
    FLD-family protocol is one seed group."""
    from repro.core.seed_prep import prep_stats
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(protocol="fld"), CH, p_up_dbm=(23.0, 40.0))
    assert len(grid.seed_groups()) == 1
    prep_stats.reset()
    runner = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty)
    assert prep_stats.runs == 1
    res = runner.run()
    _assert_equivalent(res, run_pointwise(CNN(), grid, dev_x, dev_y, tx, ty))


def test_memoized_points_share_padded_seed_rows(data):
    """Points of one seed group share one prep result object, and the
    stacked (G, Nmax, ...) padded consts carry bitwise-identical rows for
    them (padding runs once per unique set)."""
    import numpy as np
    from repro.sweep.engine import _pad_seed_sets
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(), CH, eta=(0.01, 0.02))
    runner = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty)
    # the memo handed both points the same object; no quadratic reprep
    assert runner.seed_memo.hits == 1 and runner.seed_memo.misses == 1
    assert runner.seed_sets[0] is runner.seed_sets[1]
    px, py, n = _pad_seed_sets(runner.seed_sets, 10)
    np.testing.assert_array_equal(px[0], px[1])
    np.testing.assert_array_equal(py[0], py[1])
    assert n[0] == n[1]


# ---------------------------------------------------------------------------
# Grid construction & result frames
# ---------------------------------------------------------------------------

def test_make_grid_rejects_bad_axes():
    fc = _base()
    with pytest.raises(ValueError, match="static"):
        make_grid(fc, CH, num_devices=(4, 8))     # shape-changing field
    with pytest.raises(ValueError, match="static"):
        make_grid(fc, CH, t_max_slots=(10, 100))  # draw-shaping field
    with pytest.raises(ValueError, match="unknown"):
        make_grid(fc, CH, nonsense=(1, 2))
    with pytest.raises(ValueError, match="no values"):
        make_grid(fc, CH, eta=())


def test_make_grid_points_follow_c_order():
    grid = make_grid(_base(), CH, eta=(0.01, 0.02), n_seed=(4, 6))
    assert grid.shape == (2, 2)
    etas = [fc.eta for fc, _ in grid.points]
    seeds = [fc.n_seed for fc, _ in grid.points]
    assert etas == [0.01, 0.01, 0.02, 0.02]   # last axis fastest
    assert seeds == [4, 6, 4, 6]
    labels = grid.labels()
    assert labels[1] == {"eta": 0.01, "n_seed": 6}
    assert set(FED_SWEEPABLE) & set(CH_SWEEPABLE) == set()


def test_runner_rejects_channel_population_mismatch(data):
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(), ChannelConfig(num_devices=7), eta=(0.01,))
    with pytest.raises(ValueError, match="devices"):
        SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty)


# ---------------------------------------------------------------------------
# Heterogeneous grids: protocol axis (stacked per-protocol programs) and
# per-config partitions (partition/alpha/n_local axes)
# ---------------------------------------------------------------------------

def _het_base(**kw):
    """Tiny budgets: the heterogeneous tests compare 10-point grids
    against 10 per-point trainer runs, so every knob is minimal."""
    cfg = dict(protocol="mix2fld", num_devices=4, local_iters=2,
               local_batch=16, server_iters=2, server_batch=16,
               max_rounds=2, n_seed=4, n_inverse=8, seed=0)
    cfg.update(kw)
    return FederatedConfig(**cfg)


# noniid n_local must satisfy 2*2 + 8*common; 60 = 4 + 8*7
HET_PART = PartitionSpec(scheme="iid", n_local=60, seed=0)


def test_heterogeneous_grid_matches_loop_vmapped(pool):
    """The acceptance grid: all five protocols x two partitions (IID +
    non-IID) in ONE SweepRunner call must reproduce per-point
    ``FederatedTrainer.run`` histories within 1e-6, compile exactly one
    program per distinct protocol (trace-counted), and prep seeds once
    per distinct (FLD protocol, partition) seed group."""
    from repro.core.seed_prep import prep_stats
    px, py, tx, ty = pool
    grid = make_grid(_het_base(), CH, HET_PART, protocol=PROTOCOLS,
                     partition=("iid", "noniid"))
    assert grid.shape == (5, 2) and grid.partitioned
    prep_stats.reset()
    engine_stats.reset()
    runner = SweepRunner(CNN(), grid, px, py, tx, ty)
    # 3 FLD-family protocols x 2 partitions = 6 seed groups, each
    # prepped exactly once (distinct partitions -> that many preps)
    assert runner.seed_prep_stats == {
        "groups": 6, "prep_runs": 6, "memo_hits": 0}
    assert prep_stats.runs == 6
    assert runner.programs == len(PROTOCOLS)
    res = runner.run()
    res2 = runner.run()  # warm: no re-trace
    assert engine_stats.traces == len(PROTOCOLS)
    np.testing.assert_array_equal(res.acc, res2.acc)
    _assert_equivalent(res, run_pointwise(CNN(), grid, px, py, tx, ty))


def test_heterogeneous_grid_matches_loop_sharded(pool):
    """Same contract on the ``shard_devices`` round-loop path (device
    axis on the "data" mesh inside each per-protocol program)."""
    px, py, tx, ty = pool
    grid = make_grid(_het_base(shard_devices=True), CH, HET_PART,
                     protocol=PROTOCOLS, partition=("iid", "noniid"))
    runner = SweepRunner(CNN(), grid, px, py, tx, ty)
    assert runner.mesh is not None and runner.programs == len(PROTOCOLS)
    res = runner.run()
    _assert_equivalent(res, run_pointwise(CNN(), grid, px, py, tx, ty))


def test_ragged_n_local_axis_pads_and_masks(pool):
    """An n_local axis stacks ragged partitions (padded to the grid
    maximum); the traced per-config batch-draw bound must keep every
    point bitwise-equal to its per-point loop run."""
    px, py, tx, ty = pool
    grid = make_grid(_het_base(), CH, n_local=(60, 100))
    assert grid.partitioned  # partition axes imply a default base spec
    runner = SweepRunner(CNN(), grid, px, py, tx, ty)
    # distinct n_local -> distinct partitions -> two preps
    assert runner.seed_prep_stats["prep_runs"] == 2
    res = runner.run()
    _assert_equivalent(res, run_pointwise(CNN(), grid, px, py, tx, ty))


def test_partition_axis_memoizes_seed_prep_per_partition(pool):
    """(partition x eta) grid: eta replicas inside each partition's seed
    group are memo hits; exactly #partitions preps run."""
    from repro.core.seed_prep import prep_stats
    px, py, tx, ty = pool
    grid = make_grid(_het_base(), CH, HET_PART,
                     partition=("iid", "noniid"), eta=(0.01, 0.02))
    prep_stats.reset()
    runner = SweepRunner(CNN(), grid, px, py, tx, ty)
    assert prep_stats.runs == 2
    assert runner.seed_prep_stats == {
        "groups": 2, "prep_runs": 2, "memo_hits": 2}
    # C-order: (iid, .01), (iid, .02), (noniid, .01), (noniid, .02)
    assert runner.seed_sets[0] is runner.seed_sets[1]
    assert runner.seed_sets[2] is runner.seed_sets[3]
    assert runner.seed_sets[0] is not runner.seed_sets[2]


def test_codec_axis_matches_loop_both_structural_groups(data):
    """A codec axis is structural: one program per (protocol, codec
    family), numeric codec params traced inside.  Every point — the
    identity ones (the pre-pipeline round body) and the stochastic
    codecs (shared stage functions + mirrored key schedules) — must
    reproduce its per-point loop history within 1e-6."""
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_het_base(), CH, protocol=("fd", "mix2fld"),
                     codec=("identity", "quantize"), quant_bits=(4, 8))
    assert grid.shape == (2, 2, 2)
    assert len(grid.program_groups()) == 4       # 2 protocols x 2 codecs
    assert len(grid.protocol_groups()) == 2
    engine_stats.reset()
    runner = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty)
    assert runner.programs == 4
    res = runner.run()
    assert engine_stats.traces == 4
    _assert_equivalent(res, run_pointwise(CNN(), grid, dev_x, dev_y,
                                          tx, ty))
    # frames carry the frontier fields: uplink bits shrink with the bit
    # width, epsilon stays None off the dp_gaussian family
    for row in res.frames():
        assert row["dp_epsilon"] is None
        want = 100 * (row["quant_bits"] if row["codec"] == "quantize"
                      else 32)
        assert row["uplink_bits"] == want


def test_dp_codec_grid_accounts_epsilon(data):
    """dp_gaussian grid points carry the closed-form cumulative epsilon
    (monotone in sigma^-1) in their result frames, and still match the
    loop path despite the traced per-config noise scale."""
    from repro.core.privacy import gaussian_epsilon
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_het_base(), CH, codec=("dp_gaussian",),
                     dp_sigma=(0.5, 2.0))
    runner = SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty)
    assert runner.programs == 1                  # sigma sweeps traced
    res = runner.run()
    _assert_equivalent(res, run_pointwise(CNN(), grid, dev_x, dev_y,
                                          tx, ty))
    rows = res.frames()
    R = grid.points[0][0].max_rounds
    for row, sigma in zip(rows, (0.5, 2.0)):
        assert row["dp_epsilon"] == pytest.approx(
            gaussian_epsilon(sigma, 1e-5, R))
    assert rows[0]["dp_epsilon"] > rows[1]["dp_epsilon"]


def test_protocol_axis_validates_names():
    with pytest.raises(ValueError, match="mix2lfd.*not a registered"):
        make_grid(_het_base(), CH, protocol=("fl", "mix2lfd"))
    with pytest.raises(ValueError, match="zstd.*not a registered codec"):
        make_grid(_het_base(), CH, codec=("identity", "zstd"))
    with pytest.raises(ValueError, match="not a registered partition"):
        make_grid(_het_base(), CH, partition=("iid", "pathological"))
    # unknown axes fail with the full axis listing, not a KeyError
    with pytest.raises(ValueError, match="unknown field.*partition"):
        make_grid(_het_base(), CH, protocl=("fl",))
    assert not (set(PART_SWEEPABLE)
                & (set(FED_SWEEPABLE) | set(CH_SWEEPABLE)))


def test_partitioned_grid_rejects_prepartitioned_data(pool, data):
    px, py, tx, ty = pool
    dev_x, dev_y, _, _ = data
    grid = make_grid(_het_base(), CH, partition=("iid", "noniid"))
    with pytest.raises(ValueError, match="flat sample pool"):
        SweepRunner(CNN(), grid, dev_x, dev_y, tx, ty)
    plain = make_grid(_het_base(), CH, eta=(0.01,))
    with pytest.raises(ValueError, match="pre-partitioned"):
        SweepRunner(CNN(), plain, px, py, tx, ty)


def test_heterogeneous_frames_carry_axis_labels(pool):
    px, py, tx, ty = pool
    grid = make_grid(_het_base(), CH, HET_PART,
                     protocol=("fl", "mix2fld"), partition=("iid",))
    res = run_sweep(CNN(), grid, px, py, tx, ty)
    rows = res.frames()
    assert [r["protocol"] for r in rows] == ["fl", "mix2fld"]
    payload = res.to_payload()
    assert payload["protocols"] == ["fl", "mix2fld"]
    assert res.history(1)["protocol"] == "mix2fld"


def test_result_frames_and_payload(data):
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(), CH, n_seed=(4, 6))
    res = run_sweep(CNN(), grid, dev_x, dev_y, tx, ty)
    rows = res.frames()
    assert len(rows) == 2 and rows[0]["n_seed"] == 4
    assert all(np.isfinite(r["final_acc"]) for r in rows)
    assert all(len(r["acc"]) == 3 for r in rows)
    # cum_time_s amortizes the sweep wall clock on top of channel latency
    assert rows[0]["cum_time_s"] > sum(res.history(0)["round_latency_s"])
    payload = res.to_payload()
    import json
    assert json.loads(json.dumps(payload))["grid_shape"] == [2]


# ---------------------------------------------------------------------------
# Model/task axes: registry-built per-group programs, per-task data pools,
# and mixed-architecture FD cohorts
# ---------------------------------------------------------------------------

def test_model_task_axes_match_loop_per_group():
    """protocol x model x task grid: exactly one compiled program per
    structural (protocol, codec, cohort, model, task) group, every point
    equivalent to its per-point loop run (registry-built models,
    per-task procedural pools/test sets)."""
    grid = make_grid(_het_base(), CH, HET_PART,
                     protocol=("fd", "mix2fld"),
                     model=("cnn", "mlp"),
                     task=("digits", "speech"))
    assert grid.tasked and grid.partitioned and grid.size == 8
    engine_stats.reset()
    runner = SweepRunner(None, grid)
    assert runner.programs == len(grid.program_groups()) == 8
    res = runner.run()
    res2 = runner.run()  # warm: no re-trace
    assert engine_stats.traces == 8
    np.testing.assert_array_equal(res.acc, res2.acc)
    _assert_equivalent(res, run_pointwise(None, grid,
                                          task_data=runner.task_data))
    rows = res.frames()
    assert {r["model"] for r in rows} == {"cnn", "mlp"}
    assert {r["task"] for r in rows} == {"digits", "speech"}


def test_model_axis_sharded_matches_loop():
    """A homogeneous model axis under ``shard_devices`` (per-group
    registry models on the "data" mesh)."""
    grid = make_grid(_het_base(shard_devices=True), CH, HET_PART,
                     model=("cnn", "mlp"))
    td = make_task_data(grid)
    runner = SweepRunner(None, grid, task_data=td)
    assert runner.mesh is not None and runner.programs == 2
    res = runner.run()
    _assert_equivalent(res, run_pointwise(None, grid, task_data=td))


def test_mixed_architecture_cohort_matches_loop():
    """The workload FL structurally cannot express: a
    {cnn, mlp, transformer} FD cohort runs as ONE compiled program per
    group and matches the loop path bitwise-or-1e-6; the fl protocol
    refuses mixed cohorts with a clear error."""
    grid = make_grid(_het_base(protocol="fd"), CH, HET_PART,
                     model=("cnn", "cnn+mlp+transformer"))
    td = make_task_data(grid)
    runner = SweepRunner(None, grid, task_data=td)
    assert runner.programs == 2
    res = runner.run()
    _assert_equivalent(res, run_pointwise(None, grid, task_data=td))
    assert res.history(0)["model"] == "cnn"
    assert res.history(1)["model"] == "cnn+mlp+transformer"
    # the per-arch output tables genuinely differ from the cnn-only run
    assert not np.allclose(res.loss[0], res.loss[1])
    with pytest.raises(ValueError, match="cannot mix architectures"):
        _het_base(protocol="fl", model="cnn+mlp")


def test_cnn_digits_sweep_stays_golden(data):
    """The pre-refactor gate: the default model="cnn", task="digits"
    grid over all five protocols must reproduce the recorded golden
    histories — and the registry-built program (model=None) must be
    bit-identical to the explicit ``CNN()`` one."""
    from test_protocols import GOLDEN
    dev_x, dev_y, tx, ty = data
    grid = make_grid(_base(), CH, protocol=PROTOCOLS)
    res = run_sweep(None, grid, dev_x, dev_y, tx, ty)
    res_explicit = run_sweep(CNN(), grid, dev_x, dev_y, tx, ty)
    np.testing.assert_array_equal(res.acc, res_explicit.acc)
    np.testing.assert_array_equal(res.loss, res_explicit.loss)
    for g, (fc, _) in enumerate(grid.points):
        want = GOLDEN[fc.protocol]
        h = res.history(g)
        np.testing.assert_allclose(h["acc"], want["acc"], atol=1e-4,
                                   err_msg=fc.protocol)
        np.testing.assert_allclose(h["loss"], want["loss"], atol=1e-4,
                                   err_msg=fc.protocol)
        np.testing.assert_allclose(h["round_latency_s"],
                                   want["latency_s"], rtol=1e-6)
        assert h["model"] == "cnn" and h["task"] == "digits"


def test_model_task_axes_validate(pool):
    px, py, tx, ty = pool
    with pytest.raises(ValueError, match="unknown model"):
        make_grid(_het_base(), CH, model=("cnn", "resnet"))
    with pytest.raises(ValueError, match="unknown task"):
        make_grid(_het_base(), CH, task=("digits", "imagenet"))
    # model/task-structural grids build from the registry
    grid = make_grid(_het_base(), CH, HET_PART, model=("cnn", "mlp"))
    with pytest.raises(ValueError, match="pass model=None"):
        SweepRunner(CNN(), grid, px, py, tx, ty)
    # tasked grids generate their own pools/test sets
    tgrid = make_grid(_het_base(), CH, task=("digits", "cifar"))
    with pytest.raises(ValueError, match="per-task"):
        SweepRunner(None, tgrid, px, py, tx, ty)
