"""Regenerate the results appendix of EXPERIMENTS.md from the JSON results
(dry-run records, protocol runs, privacy tables). Idempotent: replaces
everything after the RESULTS marker."""
from __future__ import annotations

import json
import os

from .bench_roofline import load_records, sync_comparison, table

ROOT = os.path.join(os.path.dirname(__file__), "..")
MARKER = "<!-- GENERATED RESULTS BELOW — benchmarks/make_experiments.py -->"


def _load(name):
    p = os.path.join(ROOT, "benchmarks", "results", f"{name}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def dryrun_summary():
    recs = [r for r in load_records()
            if r["shape"] not in ("fl_sync", "fd_sync")
            and "+donate" not in r["mesh"]]
    ok = sum(r["status"] == "ok" for r in recs)
    sk = sum(r["status"] == "skipped" for r in recs)
    er = sum(r["status"] == "error" for r in recs)
    lines = [f"Status: **{ok} compiled ok, {sk} documented skips, "
             f"{er} errors** (files: benchmarks/results/dryrun/)."]
    lines.append("")
    lines.append("| arch | shape | mesh | peak GiB | native est. GiB | "
                 "compile s |")
    lines.append("|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "ok":
            m = r["memory"]
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                f"| {m['peak_bytes']/2**30:.2f} "
                f"| {m.get('native_peak_estimate', m['peak_bytes'])/2**30:.2f} "
                f"| {r['compile_s']} |")
        elif r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                         f"| skip | — | — |")
    return "\n".join(lines)


def roofline_tables():
    out = ["```", "== 16x16 (single pod) =="]
    out += table("16x16")
    out += ["", "== 2x16x16 (multi-pod) =="]
    out += table("2x16x16")
    out += ["", "== FL vs FD sync steps (2x16x16): cross-pod bytes =="]
    out += sync_comparison()
    out.append("```")
    return "\n".join(out)


def protocol_tables():
    res = _load("protocols_fig2")
    if not res:
        return "(protocol run pending)"
    lines = ["| setting | protocol | final acc | uplink ok/round | "
             "converged | cum time s |", "|---|---|---|---|---|---|"]
    for k in sorted(res):
        v = res[k]
        proto, dist, chan = k.split("_")
        lines.append(
            f"| {dist}/{chan} | {proto} | {v['acc'][-1]:.3f} "
            f"| {v['uplink_ok']} | {v['converged_round']} "
            f"| {v['cum_time_s'][-1]:.1f} |")
    first = next(iter(res.values()))
    if "programs" in first:
        lines.append("")
        lines.append(
            f"All cells above come from ONE heterogeneous sweep call "
            f"(protocol x partition x channel grid; "
            f"{first['programs']} compiled programs — one per distinct "
            f"protocol — {first['wall_s']}s total).")
    return "\n".join(lines)


def protocol_table1():
    """Table I: cross-protocol comparison (final accuracy per data split
    and channel regime, convergence round under the asymmetric channel),
    pivoted from the same heterogeneous-sweep results as Fig. 2."""
    res = _load("protocols_fig2")
    if not res:
        return "(protocol run pending)"
    protos, cells = [], {}
    for k, v in sorted(res.items()):
        proto, dist, chan = k.split("_")
        if proto not in protos:
            protos.append(proto)
        cells[(proto, dist, chan)] = v
    cols = [("iid", "asym"), ("iid", "sym"), ("noniid", "asym"),
            ("noniid", "sym")]
    lines = ["| protocol | " + " | ".join(f"{d}/{c} acc" for d, c in cols)
             + " | converged (noniid/asym) |",
             "|---" * (len(cols) + 2) + "|"]
    for p in protos:
        row = [f"| {p} "]
        for d, c in cols:
            v = cells.get((p, d, c))
            row.append(f"| {v['acc'][-1]:.3f} " if v else "| — ")
        v = cells.get((p, "noniid", "asym"))
        row.append(f"| {v['converged_round'] if v else '—'} |")
        lines.append("".join(row))
    return "\n".join(lines)


def privacy_tables():
    res = _load("privacy_tables")
    if not res:
        return "(privacy run pending)"
    lams = sorted(res["mixup_tab2"], key=float)
    l1 = "| lambda | " + " | ".join(lams) + " |"
    l2 = "|---" * (len(lams) + 1) + "|"
    l3 = "| Mixup (Tab. II) | " + " | ".join(
        f"{res['mixup_tab2'][l]:.3f}" for l in lams) + " |"
    l4 = "| Mix2up (Tab. III) | " + " | ".join(
        f"{res['mix2up_tab3'][l]:.3f}" for l in lams) + " |"
    return "\n".join([l1, l2, l3, l4])


def payload_ratio_table():
    res = _load("payload_latency")
    if not res:
        return "(payload run pending)"
    r = res["ratios"]
    lines = ["| ratio | value |", "|---|---|"]
    for k in sorted(r):
        lines.append(f"| {k} | {r[k]:.1f}x |")
    lines.append("")
    lines.append("The amortized 10-round Mix2FLD-vs-FL uplink reduction "
                 "is the paper's 42.4x (asserted in bench_payload, gated "
                 "by check_regression).")
    return "\n".join(lines)


def payload_frontier_table():
    """Accuracy vs uplink bits vs epsilon: the link-codec frontier from
    ONE heterogeneous protocol x codec x parameter sweep."""
    res = _load("payload_frontier")
    if not res:
        return "(frontier run pending)"
    lines = ["| protocol | codec | uplink bits/round | total uplink bits "
             "| epsilon | final acc |", "|---|---|---|---|---|---|"]
    for row in res["frontier"]:
        codec = row["codec"]
        if codec == "quantize":
            codec = f"quantize{row['quant_bits']}"
        elif codec == "dp_gaussian":
            codec = f"dp_gaussian(sigma={row['dp_sigma']})"
        eps = row["dp_epsilon"]
        lines.append(
            f"| {row['protocol']} | {codec} | {row['uplink_bits']:.0f} "
            f"| {row['uplink_bits_total']:.0f} "
            f"| {'—' if eps is None else f'{eps:.2f}'} "
            f"| {row['final_acc']:.3f} |")
    lines.append("")
    lines.append(
        f"{res['grid_points']} grid points from ONE heterogeneous sweep "
        f"call ({res['programs']} compiled programs — one per (protocol, "
        f"codec family) — {res['wall_s']}s total, "
        f"{'quick' if res.get('quick') else 'full'} regime, "
        f"{res['rounds']} rounds).  Identity rows are the bitwise "
        f"baseline; quantize trades uplink bits for accuracy; "
        f"dp_gaussian trades epsilon for accuracy at unchanged bits.")
    return "\n".join(lines)


def seed_sweep_table():
    res = _load("seed_sweep")
    if not res:
        return "(seed sweep pending)"
    lines = ["| (N_S, N_I) | final acc | cum time s | round-1 latency s |",
             "|---|---|---|---|"]
    for k, v in res.items():
        lines.append(f"| {k} | {v['final_acc']:.3f} | {v['cum_time_s']:.1f} "
                     f"| {v['round1_latency_s']:.3f} |")
    return "\n".join(lines)


def sweep_engine_table():
    res = _load("sweep_engine")
    if not res:
        return "(sweep engine run pending)"
    shape = "x".join(str(s) for s in res["grid_shape"])
    lines = [
        "| grid | rounds | per-point loop s | sweep cold s | sweep warm s "
        "| warm speedup |", "|---|---|---|---|---|---|",
        f"| {shape} ({res['grid_points']} pts) | {res['rounds']} "
        f"| {res['loop_s']:.1f} | {res['sweep_cold_s']:.1f} "
        f"| {res['sweep_warm_s']:.1f} | {res['speedup_warm']:.1f}x |",
        "",
        f"Max |acc| deviation of the compiled sweep vs the per-point loop "
        f"across the grid: {res['max_abs_acc_dev_vs_loop']:.2e} "
        f"(equivalence tests: tests/test_sweep.py).",
    ]
    return "\n".join(lines)


def service_table():
    """Continuous-serving driver: accuracy per round under device churn
    + straggler timeouts, plus the checkpoint-overhead and resume-
    fidelity headline numbers (benchmarks/bench_service.py)."""
    res = _load("service")
    if not res:
        return "(service run pending)"
    lines = ["| round | acc | active devices | stragglers dropped "
             "| uplinks decoded |", "|---|---|---|---|---|"]
    for r in res.get("rounds_detail", []):
        lines.append(f"| {r['round']} | {r['acc']:.3f} | {r['n_active']} "
                     f"| {r['n_straggle']} | {r['uplink_ok']} |")
    lines.append("")
    lines.append(
        f"mix2fld, {res['num_devices']}-device pool, churn "
        f"p_active={res['p_active']} — every round checkpointed.  "
        f"Per-round checkpointing sustains "
        f"{res['ckpt_on_off_ratio']:.2f}x the checkpoint-off round "
        f"throughput ({res['ckpt_rounds_per_s']:.2f} vs "
        f"{res['nockpt_rounds_per_s']:.2f} rounds/s); restoring the "
        f"halfway checkpoint took {res['restore_s'] * 1e3:.0f} ms and "
        f"reproduced the uninterrupted run's remaining "
        f"{res['tail_rounds']} rounds with max record deviation "
        f"{res['restore_tail_max_dev']:.1e} (gated at 1e-6 by "
        f"check_regression; docs/serving.md).")
    return "\n".join(lines)


def sampling_table():
    """Client sampling: cohort-sized compiled rounds at a fixed pool
    (benchmarks/bench_sampling.py).  Previously hand-written in
    EXPERIMENTS.md; generated here so regeneration keeps it."""
    res = _load("sampling")
    if not res:
        return "(sampling run pending)"
    pool = res["pool"]
    lines = ["| sample_ratio | cohort / pool | warm s/sweep | rounds/s "
             "| vs full |", "|---|---|---|---|---|"]
    full = res["ratios"]["1.0"]["rounds_per_s"]
    for k in sorted(res["ratios"], key=float, reverse=True):
        v = res["ratios"][k]
        lines.append(
            f"| {k} | {v['cohort']} / {pool} | {v['warm_s']:.3f} "
            f"| {v['rounds_per_s']:.1f} "
            f"| {v['rounds_per_s'] / full:.2f}x |")
    lines.append("")
    lines.append(
        f"fd protocol, TinyNet probe, {res['rounds']} rounds, compiled "
        f"grid path ({'quick' if res.get('quick') else 'full'} regime; "
        f"`python -m benchmarks.run --quick sampling`).  "
        f"`sample_ratio=1.0` with a non-default `sample_seed` deviates "
        f"{res['ratio1_max_dev']:.1e} from the unsampled program (gated "
        f"at exactly 0 by check_regression).  The pod-scale acceptance "
        f"test runs a `sample_ratio=0.5` sweep at D_pool=10^4 through "
        f"`SweepRunner` with sweep-vs-loop equivalence and a "
        f"participation-only DP ledger (`tests/test_sampling.py`, "
        f"marker `slow`).  Under 50% churn over 6 rounds the busiest "
        f"device joins 4, so `epsilon_device_max` composes over 4 "
        f"rounds and sits strictly below the all-rounds `epsilon` — "
        f"the participation-accounting regression the sampling PR "
        f"fixed (churn and the sampler draw from per-mechanism "
        f"disjoint streams, so composing them stays unbiased).")
    return "\n".join(lines)


def models_table():
    """Heterogeneous-architecture FD: per-(protocol, task) cells of the
    ONE protocol x model x task sweep, mixed {cnn, mlp, transformer}
    cohort vs its homogeneous baselines (benchmarks/bench_models.py)."""
    res = _load("models")
    if not res:
        return "(models run pending)"
    lines = ["| protocol/task | cnn | mlp | transformer "
             "| mixed cohort | gain vs worst |", "|---|---|---|---|---|---|"]
    for cell, v in sorted(res["cells"].items()):
        lines.append(
            f"| {cell} | {v['cnn']:.3f} | {v['mlp']:.3f} "
            f"| {v['transformer']:.3f} | **{v['mixed']:.3f}** "
            f"| {v['gain']:+.3f} |")
    lines.append("")
    lines.append(
        f"{res['grid_points']} grid points ({res['rounds']} rounds, "
        f"{'quick' if res.get('quick') else 'full'} regime) from ONE "
        f"heterogeneous sweep call: {res['programs']} compiled programs "
        f"— exactly {res['programs_per_group']:.0f} per (protocol, "
        f"codec, cohort, model, task) group — warm grid at "
        f"{res['rounds_per_s_warm']:.1f} rounds/s.  The mixed cohort "
        f"distills three architectures into one global model over the "
        f"FD (C, C) output-table uplink — a cohort FL cannot express — "
        f"and never falls below its single-worst-architecture baseline "
        f"(min gain {res['het_gain_min']:+.3f}, mean "
        f"{res['het_gain_mean']:+.3f}; gated by check_regression; "
        f"docs/models_and_tasks.md).")
    return "\n".join(lines)


def pipeline_table():
    """Pod-scale execution: the double-buffered async round program vs
    its strict-serial oracle, the measured overlap headroom, and the
    2-D (grid x device) mesh sweep (benchmarks/bench_pipeline.py)."""
    res = _load("pipeline")
    if not res:
        return "(pipeline run pending)"
    lines = ["| schedule | rounds/s | record deviation vs serial |",
             "|---|---|---|",
             f"| depth 1 (strict serial) "
             f"| {res['depth1_rounds_per_s']:.2f} | — (oracle) |",
             f"| depth 2 (double-buffered) "
             f"| {res['depth2_rounds_per_s']:.2f} "
             f"| {res['serial_max_dev']:.1e} |"]
    lines.append("")
    lines.append(
        f"fd protocol, {res['num_devices']} devices, {res['rounds']} "
        f"rounds ({'quick' if res.get('quick') else 'full'} regime; "
        f"`python -m benchmarks.run --quick pipeline`).  Per round the "
        f"link draw costs {res['channel_ms_per_round']:.1f} ms against "
        f"{res['compute_ms_per_round']:.1f} ms of residual compute, so "
        f"overlapping them exposes "
        f"{res['overlap_speedup']:.2f}x (gated >= 1.2x; wall-clock on "
        f"this host measured {res['wall_speedup_depth2']:.2f}x — a "
        f"single-core runner time-slices the two stages).  The roofline "
        f"model, fed those component times, recommends depth "
        f"{res['roofline_pipeline_depth']} on a "
        f"{tuple(res['roofline_mesh_shape'])} mesh.  The heterogeneous "
        f"{res['sweep_grid_points']}-point sweep on the 2-D "
        f"(grid x device) mesh compiled {res['sweep_programs']} "
        f"programs for {res['sweep_groups']} structural groups "
        f"({res['programs_per_group']:.1f} per group, gated at 1.0; "
        f"per-group meshes {res['sweep_mesh_shapes']}; "
        f"docs/pod_scale.md).")
    return "\n".join(lines)


def scalability_table():
    res = _load("scalability_fig3")
    if not res:
        return "(scalability run pending)"
    lines = ["| devices | mean acc | variance |", "|---|---|---|"]
    for k, v in res.items():
        lines.append(f"| {k} | {v['mean']:.3f} | {v['var']:.5f} |")
    return "\n".join(lines)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    if os.path.exists(path):
        with open(path) as f:
            text = f.read()
        head = text.split(MARKER)[0].rstrip()
    else:  # bootstrap: a fresh checkout has only the JSON results
        head = "# EXPERIMENTS\n\nReproduction results appendix " \
               "(regenerated by benchmarks/make_experiments.py)."
    body = f"""

{MARKER}

## §Repro-results

### Fig. 2 (protocol comparison; reduced budgets, relative claims)

{protocol_tables()}

### Table I (cross-protocol pivot of the same heterogeneous sweep)

{protocol_table1()}

### Tables II/III (sample privacy vs lambda, synthetic images)

{privacy_tables()}

### Payload accounting (Sec. II-C; uplink-reduction ratios)

{payload_ratio_table()}

### Link-codec frontier (accuracy vs uplink bits vs epsilon)

{payload_frontier_table()}

### (N_S, N_I) sweep

{seed_sweep_table()}

### Sweep engine (compiled grid vs per-point loop; docs/sweep_engine.md)

{sweep_engine_table()}

### Continuous serving (churn + stragglers + crash-safe resume; docs/serving.md)

{service_table()}

### Client sampling (cohort-sized rounds at a fixed pool; docs/client_sampling.md)

{sampling_table()}

### Heterogeneous-architecture FD (model x task registry sweep; docs/models_and_tasks.md)

{models_table()}

### Pod-scale execution (async rounds + 2-D mesh; docs/pod_scale.md)

{pipeline_table()}

### Fig. 3 (scalability)

{scalability_table()}

## §Dry-run-results

{dryrun_summary()}

## §Roofline-results

{roofline_tables()}
"""
    with open(path, "w") as f:
        f.write(head + body)
    print(f"EXPERIMENTS.md regenerated ({len(body)} bytes of results)")


if __name__ == "__main__":
    main()
