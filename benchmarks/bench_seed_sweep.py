"""(N_S, N_I) sweep (Fig. 2 discussion): latency-accuracy tradeoff of the
seed sample budget and the inverse-Mixup augmentation gain."""
from __future__ import annotations

from repro.channel import ChannelConfig
from repro.core.protocols import FederatedConfig, FederatedTrainer
from repro.models.cnn import CNN

from .common import protocol_dataset, save_result

SWEEP = ((10, 10), (10, 20), (50, 50), (50, 100))


def run(local_iters=100, max_rounds=5):
    dev = protocol_dataset(num_devices=10, iid=False)
    ch = ChannelConfig(num_devices=10)  # asymmetric (paper headline)
    out = {}
    for ns, ni in SWEEP:
        fc = FederatedConfig(protocol="mix2fld", num_devices=10,
                             local_iters=local_iters, local_batch=32,
                             server_iters=local_iters, max_rounds=max_rounds,
                             n_seed=ns, n_inverse=ni, seed=2)
        h = FederatedTrainer(CNN(), fc, ch).run(*dev)
        out[f"Ns{ns}_Ni{ni}"] = {
            "final_acc": h["acc"][-1],
            "cum_time_s": h["cum_time_s"][-1],
            "round1_latency_s": h["round_latency_s"][0],
        }
        print(f"(Ns={ns}, Ni={ni}): acc={h['acc'][-1]:.3f} "
              f"t={h['cum_time_s'][-1]:.1f}s")
    save_result("seed_sweep", out)
    return out


def main():
    out = run(local_iters=40, max_rounds=2)
    return [f"seed_sweep/{k},0,acc={v['final_acc']:.4f}"
            for k, v in out.items()]


if __name__ == "__main__":
    run()
