"""(N_S, N_I) sweep (Fig. 2 discussion) on the compiled sweep engine:
latency-accuracy tradeoff of the seed sample budget and the inverse-Mixup
augmentation gain — plus the engine's headline speedup measurement.

The whole grid runs as ONE jitted program (repro.sweep); the per-point
``FederatedTrainer`` loop it replaced is kept as the baseline and timed
against it.  The loop path re-traces every grid point (fresh trainer →
fresh jit caches → new shapes per (N_S, N_I) point), which is exactly the
cost the sweep amortizes away; warm sweep calls reuse the compiled scan
outright.  Numbers land in benchmarks/results/sweep_engine.json.

Config note: per-point *compute* stays linear in the grid size (the
local-SGD hot path runs interpret-mode Pallas kernels on CPU, so there is
no batching economy in the FLOPs themselves — on a real TPU the kernels
are fast and the amortization window widens), while the loop's per-point
re-trace/compile/dispatch overhead is what the sweep removes.  The
recorded grid therefore uses reduced per-point budgets (documented
below), where that overhead dominates — the regime every quick grid scan
lives in.
"""
from __future__ import annotations

import sys
import time

import jax

from repro.channel import ChannelConfig
from repro.core.protocols import FederatedConfig
from repro.core.seed_prep import SeedPrepMemo, prep_stats, prepare_seeds
from repro.models.cnn import CNN
from repro.sweep import SweepRunner, make_grid, run_pointwise

from .common import protocol_dataset, save_result

GRID_NS = (10, 30, 50)
GRID_NI = (20, 60, 100)


def run_seed_prep(G=9):
    """Loop-vs-memoized host seed prep on an eta-only G-point grid.

    eta does not determine the round-1 seed sets, so the per-point loop
    (what the sweep engine used to do) re-collects G identical sets; the
    memoized prep layer collects once and serves G-1 content-key hits.
    Numbers land in benchmarks/results/seed_prep.json.
    """
    dev_x, dev_y, _, _ = protocol_dataset(num_devices=5, iid=False)
    ch = ChannelConfig(num_devices=5)
    base = FederatedConfig(protocol="mix2fld", num_devices=5, n_seed=20,
                           n_inverse=40, seed=2)
    grid = make_grid(base, ch,
                     eta=tuple(0.005 * (k + 1) for k in range(G)))

    def point_key(fc):  # the loop path's exact key chain
        _, key = jax.random.split(jax.random.PRNGKey(fc.seed))
        return jax.random.fold_in(jax.random.fold_in(key, 1), 2)

    # warm the jit caches once so both timings measure host prep, not
    # first-call tracing
    jax.block_until_ready(
        prepare_seeds(base, dev_x, dev_y, point_key(base))["train_x"])

    prep_stats.reset()
    t0 = time.perf_counter()
    for fc, _ in grid.points:
        jax.block_until_ready(
            prepare_seeds(fc, dev_x, dev_y, point_key(fc))["train_x"])
    loop_s = time.perf_counter() - t0
    loop_runs = prep_stats.runs

    prep_stats.reset()
    memo = SeedPrepMemo()
    t0 = time.perf_counter()
    for fc, _ in grid.points:
        jax.block_until_ready(
            prepare_seeds(fc, dev_x, dev_y, point_key(fc),
                          memo=memo)["train_x"])
    memo_s = time.perf_counter() - t0

    out = {
        "grid_points": G,
        "axis": "eta",
        "loop_s": round(loop_s, 4),
        "memoized_s": round(memo_s, 4),
        "speedup": round(loop_s / memo_s, 2),
        "loop_prep_runs": loop_runs,
        "memo_prep_runs": prep_stats.runs,
        "memo_hits": memo.hits,
        # the regression gate (benchmarks/check_regression.py) compares
        # this against the committed baseline: an eta-only grid must
        # keep serving G-1 of G points from the memo
        "hit_rate": round(memo.hits / G, 4),
    }
    save_result("seed_prep", out)
    print(f"seed prep at G={G} (eta-only): loop={loop_s:.3f}s "
          f"({loop_runs} preps) memoized={memo_s:.3f}s "
          f"({prep_stats.runs} prep, {memo.hits} hits) "
          f"speedup={out['speedup']:.1f}x")
    return out


def run(local_iters=2, max_rounds=2, quick=False):
    ns, ni = GRID_NS, GRID_NI
    if quick:
        ns, ni = (10, 30), (20, 60)
    dev = protocol_dataset(num_devices=5, iid=False)
    ch = ChannelConfig(num_devices=5)  # asymmetric (paper headline)
    base = FederatedConfig(protocol="mix2fld", num_devices=5,
                           local_iters=local_iters, local_batch=8,
                           server_iters=local_iters, server_batch=8,
                           max_rounds=max_rounds, seed=2)
    grid = make_grid(base, ch, n_seed=ns, n_inverse=ni)

    # ---- per-point loop baseline (what the sweep replaced) ----
    t0 = time.perf_counter()
    loop_hs = run_pointwise(CNN(), grid, *dev)
    loop_s = time.perf_counter() - t0

    # ---- compiled sweep: cold (trace+compile+seed prep) then warm ----
    t0 = time.perf_counter()
    runner = SweepRunner(CNN(), grid, *dev)
    res = runner.run()
    cold_s = time.perf_counter() - t0
    res = runner.run()  # warm: reuses the compiled scan
    warm_s = res.wall_s

    speedup_warm = loop_s / warm_s
    speedup_cold = loop_s / cold_s
    engine = {
        "grid_shape": list(grid.shape),
        "grid_points": grid.size,
        "rounds": max_rounds,
        "local_iters": local_iters,
        "quick": bool(quick),
        "loop_s": round(loop_s, 3),
        "sweep_cold_s": round(cold_s, 3),
        "sweep_warm_s": round(warm_s, 3),
        "speedup_warm": round(speedup_warm, 2),
        "speedup_cold": round(speedup_cold, 2),
        "max_abs_acc_dev_vs_loop": max(
            max(abs(a - b) for a, b in
                zip(res.history(g)["acc"], loop_hs[g]["acc"]))
            for g in range(grid.size)),
    }
    save_result("sweep_engine", engine)

    out = {}
    for g, row in enumerate(res.frames()):
        out[f"Ns{row['n_seed']}_Ni{row['n_inverse']}"] = {
            "final_acc": row["final_acc"],
            "cum_time_s": row["cum_time_s"],
            "round1_latency_s": row["round1_latency_s"],
        }
        print(f"(Ns={row['n_seed']}, Ni={row['n_inverse']}): "
              f"acc={row['final_acc']:.3f} t={row['cum_time_s']:.1f}s")
    print(f"sweep engine: {grid.size}-pt grid loop={loop_s:.1f}s "
          f"cold={cold_s:.1f}s warm={warm_s:.1f}s "
          f"speedup warm={speedup_warm:.1f}x")
    save_result("seed_sweep", out)
    prep = run_seed_prep()
    return out, engine, prep


def main(quick=True):
    out, engine, prep = run(quick=quick)
    rows = [f"seed_sweep/{k},0,acc={v['final_acc']:.4f}"
            for k, v in out.items()]
    rows.append(f"sweep_engine/{engine['grid_points']}pt,"
                f"{engine['sweep_warm_s']*1e6:.0f},"
                f"speedup_warm={engine['speedup_warm']:.1f}x")
    rows.append(f"seed_prep/G{prep['grid_points']}_eta,"
                f"{prep['memoized_s']*1e6:.0f},"
                f"speedup={prep['speedup']:.1f}x")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv[1:])
