"""Roofline table from the dry-run results (one row per arch x shape x
mesh) + the FL-vs-FD sync-step collective comparison (the paper's uplink
asymmetry argument at pod scale)."""
from __future__ import annotations

import glob
import json
import os

from repro.roofline.analysis import improvement_hint, summarize_combo

DRYRUN = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_records():
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(mesh: str = "16x16"):
    rows = []
    for r in load_records():
        if r["mesh"] != mesh or r["shape"] in ("fl_sync", "fd_sync"):
            continue
        if r["status"] == "skipped":
            rows.append(f"{r['arch']:20s} {r['shape']:12s} {mesh:9s} "
                        f"SKIPPED ({r['reason'][:60]}...)")
        elif r["status"] == "ok":
            rows.append(summarize_combo(r))
            rows.append(f"{'':43s}-> {improvement_hint(r)}")
        else:
            rows.append(f"{r['arch']:20s} {r['shape']:12s} {mesh:9s} "
                        f"ERROR {r['error'][:60]}")
    return rows


def sync_comparison():
    """FL vs FD sync **cross-pod** bytes per arch — the paper's scarce
    uplink direction at pod granularity.  (Total collective bytes include
    the conversion's intra-pod FSDP traffic, which rides the fat
    intra-pod links — the exact asymmetry the paper exploits.)"""
    recs = {(r["arch"], r["shape"]): r for r in load_records()
            if r["shape"] in ("fl_sync", "fd_sync") and r["status"] == "ok"}
    rows = []
    for arch in sorted({a for a, _ in recs}):
        fl = recs.get((arch, "fl_sync"))
        fd = recs.get((arch, "fd_sync"))
        if not fl or not fd:
            continue
        xfl = fl.get("cross_pod_bytes_per_device", 0)
        xfd = fd.get("cross_pod_bytes_per_device", 0)
        rows.append(
            f"{arch:20s} "
            f"fl_cross={xfl/2**20:9.2f}MiB fd_cross={xfd/2**20:9.2f}MiB "
            f"cross_reduction={xfl/max(xfd,1):7.1f}x "
            f"(fd total={fd['collective_bytes_per_device']/2**20:9.1f}MiB"
            f" intra-pod)")
    return rows


def main():
    out = []
    for r in load_records():
        if r["status"] != "ok" or r["shape"] in ("fl_sync", "fd_sync"):
            continue
        t = r["roofline"]
        bound = max(t.values())
        out.append(
            f"roofline/{r['arch']}_{r['shape']}_{r['mesh']},"
            f"{bound*1e6:.0f},dom={r['dominant']}")
    for row in sync_comparison():
        parts = row.split()
        out.append(f"sync/{parts[0]},0,{parts[-1]}")
    return out


if __name__ == "__main__":
    print("== roofline 16x16 ==")
    print("\n".join(table("16x16")))
    print("== roofline 2x16x16 ==")
    print("\n".join(table("2x16x16")))
    print("== FL vs FD sync ==")
    print("\n".join(sync_comparison()))
