"""CI benchmark-regression gate for the compiled sweep path.

Compares freshly produced ``benchmarks/results/*.json`` (the ``--quick``
sweep/seed-prep benchmarks the CI ``sweeps`` job just ran) against the
*committed* baselines of the same files, with per-metric tolerances —
so a compiled-path regression (sweep-vs-loop speedup collapse, seed-prep
memo stops hitting, sweep numerics drifting off the loop path) fails the
PR instead of hiding in an artifact.

Baselines come from ``git show <ref>:<file>`` by default (the checkout's
committed state, which the benchmark run just overwrote in the working
tree), or from a directory snapshot via ``--baseline-dir``.

Gate modes:

* ``min_ratio`` — fresh >= ratio * baseline (speedups, hit rates; ratio
  below 1 absorbs machine-to-machine noise, the speedup itself is a
  wall-clock *ratio* so host speed largely cancels);
* ``max_value`` — fresh <= absolute limit (numeric equivalence drift);
* ``min_value`` — fresh >= absolute floor (same-host wall-time ratios
  with a hard acceptance bar, e.g. the async-round overlap speedup);
* ``not_above_baseline`` — fresh <= baseline (counters that must never
  grow, e.g. memoized prep runs);
* ``min_delta`` — fresh >= baseline - tol (floors for metrics that can
  be negative, e.g. log-scale privacy means, where a multiplicative
  ``min_ratio`` floor would flip direction).

Regime guard: gates only fire when the ``match`` keys (grid geometry,
quick flag) agree between fresh and baseline — comparing a quick run
against a full-run baseline would gate on noise.  A skipped gate prints
a warning; refresh the committed baselines when the regime changes.

Usage::

    python -m benchmarks.check_regression            # git HEAD baselines
    python -m benchmarks.check_regression --baseline-dir /tmp/base
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(ROOT, "benchmarks", "results")

#: (file, metric, mode, tolerance) — see module docstring for modes.
GATES = [
    # compiled sweep vs per-point loop: the engine's headline number
    {"file": "sweep_engine", "metric": "speedup_warm",
     "mode": "min_ratio", "ratio": 0.7,
     "match": ("grid_points", "rounds", "local_iters", "quick")},
    {"file": "sweep_engine", "metric": "max_abs_acc_dev_vs_loop",
     "mode": "max_value", "limit": 1e-6, "match": ()},
    # memoized host seed prep: speed and hit rate must hold
    {"file": "seed_prep", "metric": "speedup",
     "mode": "min_ratio", "ratio": 0.7, "match": ("grid_points", "axis")},
    # 0.99, not 1.0: the recorded rate is rounded to 4 decimals; a real
    # regression moves it by >= 1/G (11% at G=9), far beyond rounding
    {"file": "seed_prep", "metric": "hit_rate",
     "mode": "min_ratio", "ratio": 0.99, "match": ("grid_points", "axis")},
    {"file": "seed_prep", "metric": "memo_prep_runs",
     "mode": "not_above_baseline", "match": ("grid_points", "axis")},
    # link pipeline: the paper's amortized 10-round uplink reduction is
    # pure payload arithmetic — any drift is a codec accounting bug
    {"file": "payload_latency", "metric": "uplink_reduction_amortized_10r",
     "mode": "min_ratio", "ratio": 0.999, "match": ()},
    # continuous-serving driver: a resumed run's tail must reproduce the
    # uninterrupted run's records (the service acceptance property) ...
    {"file": "service", "metric": "restore_tail_max_dev",
     "mode": "max_value", "limit": 1e-6, "match": ()},
    # ... and the crash-safe checkpoint path must stay cheap relative to
    # a round — the on/off rounds-per-s ratio cancels host speed
    {"file": "service", "metric": "ckpt_on_off_ratio",
     "mode": "min_ratio", "ratio": 0.7,
     "match": ("rounds", "num_devices", "quick")},
    # client sampling: a half cohort must keep its throughput edge over
    # full participation (rounds/s ratio, host speed cancels) ...
    {"file": "sampling", "metric": "speedup_050",
     "mode": "min_ratio", "ratio": 0.7,
     "match": ("pool", "rounds", "quick")},
    # ... and sample_ratio=1.0 must stay the unsampled program exactly
    {"file": "sampling", "metric": "ratio1_max_dev",
     "mode": "max_value", "limit": 0.0, "match": ()},
    # Tables II/III mean sample privacy must not drop (values are
    # log-scale and can be negative, hence the additive floor)
    {"file": "privacy_tables", "metric": "tab2_mean",
     "mode": "min_delta", "tol": 0.05, "match": ("n_samples", "quick")},
    {"file": "privacy_tables", "metric": "tab3_mean",
     "mode": "min_delta", "tol": 0.05, "match": ("n_samples", "quick")},
    # async round pipeline: dispatch order must never change a bit (the
    # double-buffered path's draws are pure functions of (plan, key)) ...
    {"file": "pipeline", "metric": "serial_max_dev",
     "mode": "max_value", "limit": 0.0, "match": ()},
    # ... the depth-2 schedule must keep exposing its overlap headroom
    # (measured same-host component-time ratio, so machine speed
    # cancels; regime tuned to ~1.6x, the floor catches a draw that
    # re-serialized) ...
    {"file": "pipeline", "metric": "overlap_speedup",
     "mode": "min_value", "floor": 1.2, "match": ()},
    # ... and the 2-D (grid x device) mesh sweep must still compile one
    # program per structural group
    {"file": "pipeline", "metric": "programs_per_group",
     "mode": "max_value", "limit": 1.0, "match": ()},
    # heterogeneous model x task grid: the engine must build exactly one
    # program per structural (protocol, codec, cohort, model, task)
    # group — a second build per group means the grouping key broke
    {"file": "models", "metric": "programs_per_group",
     "mode": "max_value", "limit": 1.0, "match": ()},
    # ... the mixed {cnn, mlp, transformer} cohort's mean gain over its
    # single-worst-architecture baseline must not collapse (small
    # additive slack: final accs quantize at 1/n_test on the quick grid)
    {"file": "models", "metric": "het_gain_mean",
     "mode": "min_delta", "tol": 0.02,
     "match": ("grid_points", "rounds", "quick")},
    # ... and warm whole-grid throughput must hold.  Coarse floor: this
    # is raw wall-clock (no host-cancelling ratio exists here), so 0.25
    # absorbs runner-speed spread while still catching the failure it
    # exists for — a retrace-per-round regression drops it ~10x
    {"file": "models", "metric": "rounds_per_s_warm",
     "mode": "min_ratio", "ratio": 0.25,
     "match": ("grid_points", "rounds", "quick")},
]


def load_fresh(name: str, results_dir: str):
    path = os.path.join(results_dir, f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def load_baseline(name: str, baseline_dir: str | None, ref: str):
    if baseline_dir:
        return load_fresh(name, baseline_dir)
    rel = f"benchmarks/results/{name}.json"
    try:
        out = subprocess.run(
            ["git", "show", f"{ref}:{rel}"], cwd=ROOT, check=True,
            capture_output=True, text=True).stdout
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None
    return json.loads(out)


def derive(payload: dict | None) -> dict | None:
    """Fill metrics older baselines predate (hit_rate) from raw fields."""
    if payload is None:
        return None
    if "hit_rate" not in payload and "memo_hits" in payload \
            and "grid_points" in payload:
        payload = dict(payload)
        payload["hit_rate"] = payload["memo_hits"] / payload["grid_points"]
    if "ratios" in payload:
        payload = dict(payload)
        payload["uplink_reduction_amortized_10r"] = \
            payload["ratios"].get("fl_over_mix2fld_amortized_10r")
    for tab, metric in (("mixup_tab2", "tab2_mean"),
                        ("mix2up_tab3", "tab3_mean")):
        if tab in payload and metric not in payload:
            payload = dict(payload)
            vals = list(payload[tab].values())
            payload[metric] = sum(vals) / len(vals)
    return payload


def check_gate(gate: dict, fresh: dict, base: dict) -> tuple[bool, str]:
    """Returns (ok, message)."""
    metric = gate["metric"]
    fv = fresh.get(metric)
    mode = gate["mode"]
    if mode == "max_value":
        ok = fv is not None and fv <= gate["limit"]
        return ok, f"{metric}={fv!r} (limit {gate['limit']:g})"
    if mode == "min_value":
        ok = fv is not None and fv >= gate["floor"]
        return ok, f"{metric}={fv!r} (floor {gate['floor']:g})"
    bv = base.get(metric)
    if fv is None or bv is None:
        return False, f"{metric} missing (fresh={fv!r}, baseline={bv!r})"
    if mode == "min_ratio":
        floor = gate["ratio"] * bv
        return fv >= floor, (f"{metric}={fv:g} vs baseline {bv:g} "
                             f"(floor {floor:g} = {gate['ratio']}x)")
    if mode == "not_above_baseline":
        return fv <= bv, f"{metric}={fv!r} vs baseline {bv!r}"
    if mode == "min_delta":
        floor = bv - gate["tol"]
        return fv >= floor, (f"{metric}={fv:g} vs baseline {bv:g} "
                             f"(floor {floor:g} = baseline - "
                             f"{gate['tol']:g})")
    raise ValueError(f"unknown gate mode {mode!r}")


def run_checks(results_dir: str = RESULTS, baseline_dir: str | None = None,
               ref: str = "HEAD") -> int:
    failures = 0
    cache: dict = {}
    for gate in GATES:
        name = gate["file"]
        if name not in cache:
            cache[name] = (derive(load_fresh(name, results_dir)),
                           derive(load_baseline(name, baseline_dir, ref)))
        fresh, base = cache[name]
        tag = f"{name}.{gate['metric']}"
        if fresh is None:
            print(f"FAIL  {tag}: no fresh result in {results_dir} "
                  f"(did the benchmark step run?)")
            failures += 1
            continue
        if gate["mode"] in ("max_value", "min_value"):
            # absolute gates need no baseline — never skippable
            ok, msg = check_gate(gate, fresh, base or {})
            print(f"{'ok   ' if ok else 'FAIL '} {tag}: {msg}")
            failures += 0 if ok else 1
            continue
        if base is None:
            print(f"skip  {tag}: no committed baseline (first run? "
                  f"commit benchmarks/results/{name}.json)")
            continue
        mismatch = [k for k in gate.get("match", ())
                    if k in base and fresh.get(k) != base.get(k)]
        if mismatch:
            print(f"skip  {tag}: regime mismatch on {mismatch} "
                  f"(fresh {[fresh.get(k) for k in mismatch]} vs baseline "
                  f"{[base.get(k) for k in mismatch]}) — refresh the "
                  f"committed baseline")
            continue
        ok, msg = check_gate(gate, fresh, base)
        print(f"{'ok   ' if ok else 'FAIL '} {tag}: {msg}")
        failures += 0 if ok else 1
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--results-dir", default=RESULTS,
                    help="fresh results to check (default: "
                         "benchmarks/results)")
    ap.add_argument("--baseline-dir", default=None,
                    help="baseline snapshot dir (default: git show <ref>)")
    ap.add_argument("--ref", default="HEAD",
                    help="git ref for committed baselines (default: HEAD)")
    args = ap.parse_args(argv)
    failures = run_checks(args.results_dir, args.baseline_dir, args.ref)
    if failures:
        print(f"\n{failures} benchmark-regression gate(s) failed")
        return 1
    print("\nall benchmark-regression gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
