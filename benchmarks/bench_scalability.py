"""Fig. 3 reproduction: Mix2FLD test-accuracy distribution vs number of
devices (10 vs 50 in the paper; reduced counts documented) — plus the
seed-pipeline scaling benchmark: batched device-axis ``collect_seeds``
vs the pre-batching per-device/per-sample loop reference."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import ChannelConfig
from repro.core.mixup import (inverse_mixup, make_mixup_batch, mixup_pairs,
                              pair_symmetric)
from repro.core.protocols import FederatedConfig, FederatedTrainer
from repro.models.cnn import CNN

from .common import protocol_dataset, save_result, time_call


def _collect_seeds_loop(fc, dev_x, dev_y, key):
    """Pre-batching reference: per-device Python loop + one-sample-at-a-
    time inverse-Mixup (kept here so the speedup stays measurable)."""
    D, C = fc.num_devices, fc.num_classes
    mixed, minors, majors, dev_ids = [], [], [], []
    for d in range(D):
        k = jax.random.fold_in(key, 1000 + d)
        idx_i, idx_j = mixup_pairs(k, dev_y[d], fc.n_seed, C)
        mx, _, (mi, ma) = make_mixup_batch(
            dev_x[d], dev_y[d], idx_i, idx_j, fc.lam, C)
        mixed.append(mx)
        minors.append(mi)
        majors.append(ma)
        dev_ids.append(np.full(fc.n_seed, d))
    mixed = jnp.concatenate(mixed)
    minors = jnp.concatenate(minors)
    majors = jnp.concatenate(majors)
    pairs = pair_symmetric(np.asarray(minors), np.asarray(majors),
                           np.concatenate(dev_ids))
    inv_x, inv_y = [], []
    want_total = fc.n_inverse * D
    while len(inv_x) < want_total and len(pairs):
        for (i, j) in pairs:
            s1, s2 = inverse_mixup(mixed[i], mixed[j], fc.lam)
            inv_x.extend([s1, s2])
            inv_y.extend([int(minors[i]), int(minors[j])])
            if len(inv_x) >= want_total:
                break
    return jnp.stack(inv_x) if inv_x else mixed


def bench_seed_pipeline(num_devices: int = 50, per_device: int = 100,
                        n_seed: int = 10):
    """Wall-clock of round-1 seed collection, batched vs loop, at D=50."""
    dev_x, dev_y, _, _ = protocol_dataset(num_devices=num_devices,
                                          per_device=per_device)
    dev_x, dev_y = jnp.asarray(dev_x), jnp.asarray(dev_y)
    fc = FederatedConfig(protocol="mix2fld", num_devices=num_devices,
                         n_seed=n_seed, n_inverse=2 * n_seed)
    tr = FederatedTrainer(CNN(), fc)
    key = jax.random.PRNGKey(3)

    # warm up both paths so neither number includes one-time trace/compile
    jax.block_until_ready(tr.collect_seeds(dev_x, dev_y, key)["train_x"])
    jax.block_until_ready(_collect_seeds_loop(fc, dev_x, dev_y, key))

    t0 = time.perf_counter()
    jax.block_until_ready(tr.collect_seeds(dev_x, dev_y, key)["train_x"])
    t_batched = time.perf_counter() - t0

    t0 = time.perf_counter()
    jax.block_until_ready(_collect_seeds_loop(fc, dev_x, dev_y, key))
    t_loop = time.perf_counter() - t0

    speedup = t_loop / max(t_batched, 1e-9)
    row = (f"seed_pipeline/D{num_devices},"
           f"{t_batched*1e6:.0f},loop_us={t_loop*1e6:.0f};"
           f"speedup={speedup:.1f}x")
    print(row)
    save_result("seed_pipeline", {"batched_s": t_batched, "loop_s": t_loop,
                                  "speedup": speedup, "D": num_devices})
    return row


def bench_sharded_round(device_counts=(50, 200), local_iters: int = 5,
                        per_device: int = 50):
    """Wall-clock of one round-loop step (local SGD over all devices +
    weighted aggregation + eq. 2 output average), mesh-sharded vs vmapped.

    On a 1-chip host the sharded path measures shard_map/psum overhead
    (should be ~1x); on a multi-chip host the device axis splits across
    the mesh and the ratio becomes the scaling win."""
    out = {}
    rows = []
    for nd in device_counts:
        dev_x, dev_y, _, _ = protocol_dataset(num_devices=nd,
                                              per_device=per_device,
                                              n_test=10)
        dev_x, dev_y = jnp.asarray(dev_x), jnp.asarray(dev_y)
        times = {}
        shards = 1
        for sharded in (False, True):
            fc = FederatedConfig(protocol="mix2fld", num_devices=nd,
                                 local_iters=local_iters, local_batch=16,
                                 shard_devices=sharded)
            tr = FederatedTrainer(CNN(), fc)
            if tr.mesh is not None:
                shards = tr.mesh.devices.size
            C = fc.num_classes
            g0 = tr.model.init(jax.random.PRNGKey(0))
            dev_params = jax.tree.map(
                lambda p: jnp.broadcast_to(p, (nd,) + p.shape).copy(), g0)
            dev_gout = jnp.full((nd, C, C), 1.0 / C)
            dkeys = jax.random.split(jax.random.PRNGKey(1), nd)
            ok = jnp.ones((nd,), jnp.float32)

            def step():
                params, favg, cnt, _ = tr._local_train(
                    dev_params, dev_x, dev_y, dkeys, dev_gout,
                    jnp.asarray(True))
                g = tr._weighted_avg(params, ok * dev_x.shape[1])
                gout = tr._gout_update(favg, cnt, ok)
                return jax.tree.leaves(g) + [gout]

            # one warmup (compile) + one timed call: a round step is
            # seconds-long, so more repeats would not buy stability
            us = time_call(step, repeats=1, warmup=1)
            times["sharded" if sharded else "vmapped"] = us / 1e6
        out[nd] = dict(times, shards=shards, local_iters=local_iters,
                       per_device=per_device)
        rows.append(f"sharded_round/D{nd},{times['sharded']*1e6:.0f},"
                    f"vmapped_us={times['vmapped']*1e6:.0f};"
                    f"shards={shards}")
        print(rows[-1])
    save_result("sharded_round_loop", out)
    return rows


def run(device_counts=(5, 10, 20), seeds=(0, 1, 2), iid=True,
        local_iters=100, max_rounds=4):
    out = {}
    for nd in device_counts:
        accs = []
        for seed in seeds:
            dev = protocol_dataset(num_devices=nd, per_device=500, iid=iid,
                                   seed=seed)
            ch = ChannelConfig(num_devices=nd, p_up_dbm=40.0)  # symmetric
            fc = FederatedConfig(protocol="mix2fld", num_devices=nd,
                                 local_iters=local_iters, local_batch=32,
                                 server_iters=local_iters,
                                 max_rounds=max_rounds, seed=seed)
            h = FederatedTrainer(CNN(), fc, ch).run(*dev)
            accs.append(h["acc"][-1])
        out[nd] = {"mean": float(np.mean(accs)), "var": float(np.var(accs)),
                   "accs": accs}
        print(f"devices={nd}: mean={out[nd]['mean']:.3f} "
              f"var={out[nd]['var']:.5f}")
    save_result("scalability_fig3", out)
    return out


def main():
    rows = [bench_seed_pipeline()]
    rows += bench_sharded_round()
    out = run(device_counts=(5, 10), seeds=(0, 1), local_iters=60,
              max_rounds=3)
    for nd, v in out.items():
        rows.append(f"fig3/devices{nd},0,mean={v['mean']:.4f};"
                    f"var={v['var']:.6f}")
    return rows


if __name__ == "__main__":
    run()
