"""Fig. 3 reproduction: Mix2FLD test-accuracy distribution vs number of
devices (10 vs 50 in the paper; reduced counts documented)."""
from __future__ import annotations

import numpy as np

from repro.channel import ChannelConfig
from repro.core.protocols import FederatedConfig, FederatedTrainer
from repro.models.cnn import CNN

from .common import protocol_dataset, save_result


def run(device_counts=(5, 10, 20), seeds=(0, 1, 2), iid=True,
        local_iters=100, max_rounds=4):
    out = {}
    for nd in device_counts:
        accs = []
        for seed in seeds:
            dev = protocol_dataset(num_devices=nd, per_device=500, iid=iid,
                                   seed=seed)
            ch = ChannelConfig(num_devices=nd, p_up_dbm=40.0)  # symmetric
            fc = FederatedConfig(protocol="mix2fld", num_devices=nd,
                                 local_iters=local_iters, local_batch=32,
                                 server_iters=local_iters,
                                 max_rounds=max_rounds, seed=seed)
            h = FederatedTrainer(CNN(), fc, ch).run(*dev)
            accs.append(h["acc"][-1])
        out[nd] = {"mean": float(np.mean(accs)), "var": float(np.var(accs)),
                   "accs": accs}
        print(f"devices={nd}: mean={out[nd]['mean']:.3f} "
              f"var={out[nd]['var']:.5f}")
    save_result("scalability_fig3", out)
    return out


def main():
    out = run(device_counts=(5, 10), seeds=(0, 1), local_iters=60,
              max_rounds=3)
    rows = []
    for nd, v in out.items():
        rows.append(f"fig3/devices{nd},0,mean={v['mean']:.4f};"
                    f"var={v['var']:.6f}")
    return rows


if __name__ == "__main__":
    run()
