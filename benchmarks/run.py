"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Full (paper-scale) variants
run via each module's __main__; here the quick variants keep the whole
suite CPU-tractable.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_kernels, bench_payload, bench_privacy,
                   bench_protocols, bench_roofline, bench_scalability,
                   bench_seed_sweep)

    modules = [
        ("payload", bench_payload),      # Sec. II-C / IV payload ratios
        ("privacy", bench_privacy),      # Tables II & III
        ("kernels", bench_kernels),      # Pallas kernels vs oracles
        ("roofline", bench_roofline),    # dry-run roofline terms
        ("protocols", bench_protocols),  # Fig. 2 (quick)
        ("seed_sweep", bench_seed_sweep),  # (N_S, N_I) tradeoff (quick)
        ("scalability", bench_scalability),  # Fig. 3 (quick)
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for row in mod.main():
                print(row)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
