"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Full (paper-scale) variants
run via each module's __main__; here the quick variants keep the whole
suite CPU-tractable.  Protocol-grid modules (protocols, seed_sweep) run
on the compiled sweep engine (repro.sweep) — whole grids per program —
and seed_sweep also records the engine's sweep-vs-loop speedup
(benchmarks/results/sweep_engine.json).

Select a subset by name: ``python -m benchmarks.run seed_sweep kernels``.
``--quick`` propagates to every module whose ``main`` accepts a
``quick`` keyword (payload frontier, privacy tables) — the regime CI
runs and the committed baselines are generated under.
"""
from __future__ import annotations

import inspect
import sys
import traceback


def main(argv=None) -> None:
    from . import (bench_kernels, bench_models, bench_payload,
                   bench_pipeline, bench_privacy, bench_protocols,
                   bench_roofline, bench_sampling, bench_scalability,
                   bench_seed_sweep, bench_service)

    modules = [
        ("payload", bench_payload),      # Sec. II-C / IV payload ratios
        ("privacy", bench_privacy),      # Tables II & III
        ("kernels", bench_kernels),      # Pallas kernels vs oracles
        ("roofline", bench_roofline),    # dry-run roofline terms
        ("protocols", bench_protocols),  # Fig. 2 (quick, sweep engine)
        ("seed_sweep", bench_seed_sweep),  # (N_S, N_I) grid + engine speedup
        ("scalability", bench_scalability),  # Fig. 3 (quick)
        ("sampling", bench_sampling),    # rounds/s vs sample_ratio
        ("service", bench_service),      # ckpt overhead + resume fidelity
        ("pipeline", bench_pipeline),    # async rounds + 2-D mesh sweep
        ("models", bench_models),        # heterogeneous model x task grid
    ]
    args = list(sys.argv[1:] if argv is None else argv)
    quick = "--quick" in args
    wanted = {a for a in args if a != "--quick"}
    if wanted:
        unknown = wanted - {n for n, _ in modules}
        if unknown:
            raise SystemExit(f"unknown benchmark module(s): "
                             f"{sorted(unknown)}; "
                             f"available: {[n for n, _ in modules]}")
        modules = [(n, m) for n, m in modules if n in wanted]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            kwargs = {}
            if quick and "quick" in inspect.signature(mod.main).parameters:
                kwargs["quick"] = True
            for row in mod.main(**kwargs):
                print(row)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
