"""Sec. II-C / IV payload + latency accounting, and the link-codec
frontier.

Two halves:

* **Accounting** — per-protocol payload bits from the codec-aware
  ``round_payload_bits`` (first-round vs steady-state is an explicit
  pair, so the FLD family's seed-upload asymmetry cannot be dropped by a
  forgotten kwarg), link latency under the paper's channel, and the
  paper's headline uplink-reduction ratios.  The amortized 10-round
  Mix2FLD-vs-FL ratio must land on the paper's 42.4x — asserted here and
  gated by ``check_regression``.

* **Frontier** — ONE heterogeneous ``SweepRunner`` call sweeping
  ``protocol`` x ``codec`` x ``quant_bits`` x ``dp_sigma`` (codec
  families compile structurally — one program per (protocol, family) —
  while the numeric parameters batch inside), producing the
  accuracy-vs-uplink-bits-vs-epsilon frontier
  (``benchmarks/results/payload_frontier.json``, plotted into
  EXPERIMENTS.md).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.channel import ChannelConfig, round_payload_bits
from repro.channel.model import simulate_link
from repro.core.protocols import PROTOCOLS, FederatedConfig
from repro.data import PartitionSpec
from repro.models.cnn import CNN
from repro.sweep import SweepRunner, make_grid

from .common import sample_pool, save_result

N_MOD = 12544       # paper MLP: 28*28*16 + 16*10 weights
N_L = 10
B_S = 6272          # 8 bit * 28 * 28 seed sample
N_S = 10
AMORTIZE_ROUNDS = 10


def run():
    cfg = ChannelConfig()
    out = {}
    for proto in PROTOCOLS:
        pay = round_payload_bits(proto, n_mod=N_MOD, n_labels=N_L,
                                 sample_bits=B_S, n_seed=N_S)
        q8 = round_payload_bits(proto, n_mod=N_MOD, n_labels=N_L,
                                sample_bits=B_S, n_seed=N_S,
                                codec="quantize8")
        lat_up, ok_up = simulate_link(jax.random.PRNGKey(0), cfg,
                                      pay.up_steady, True, 2000)
        lat_dn, ok_dn = simulate_link(jax.random.PRNGKey(1), cfg, pay.dn,
                                      False, 2000)
        out[proto] = {
            "uplink_bits_first_round": pay.up_first,
            "uplink_bits_steady": pay.up_steady,
            "downlink_bits": pay.dn,
            "uplink_bits_steady_quantize8": q8.up_steady,
            "uplink_success_rate": float(np.mean(np.asarray(ok_up))),
            "uplink_mean_latency_slots": float(np.mean(np.asarray(lat_up))),
            "downlink_success_rate": float(np.mean(np.asarray(ok_dn))),
        }
    fl = round_payload_bits("fl", n_mod=N_MOD, n_labels=N_L)
    mx = round_payload_bits("mix2fld", n_mod=N_MOD, n_labels=N_L,
                            sample_bits=B_S, n_seed=N_S)
    R = AMORTIZE_ROUNDS
    amortized = (R * fl.up_steady) / (mx.up_first + (R - 1) * mx.up_steady)
    out["ratios"] = {
        "fl_over_fd_steady": fl.up_steady /
            out["fd"]["uplink_bits_steady"],
        "fl_over_mix2fld_steady": fl.up_steady / mx.up_steady,
        "fl_over_mix2fld_first": fl.up_steady / mx.up_first,
        "fl_over_mix2fld_amortized_10r": amortized,
    }
    # the paper's headline number: amortized over 10 rounds the seed
    # upload is a one-off, and Mix2FLD moves 42.4x fewer uplink bits
    assert abs(amortized - 42.4) < 0.1, (
        f"amortized 10-round uplink reduction drifted: {amortized:.2f} "
        f"(paper: 42.4)")
    save_result("payload_latency", out)
    return out


def run_frontier(quick=False):
    """The accuracy-vs-bits-vs-epsilon frontier in ONE heterogeneous
    sweep: every (protocol, codec, parameter) cell is a grid point, one
    compiled program per (protocol, codec family)."""
    protocols = ("fd", "mix2fld") if quick else ("fl", "fd", "mix2fld")
    if quick:
        li, si, rounds, D, n_local = 15, 15, 2, 5, 100
    else:
        li, si, rounds, D, n_local = 100, 100, 6, 10, 300
    pool = sample_pool(D * n_local, seed=0)
    base = FederatedConfig(
        protocol="mix2fld", num_devices=D, local_iters=li, local_batch=32,
        server_iters=si, server_batch=32, max_rounds=rounds, seed=1)
    ch = ChannelConfig(num_devices=D)
    grid = make_grid(base, ch, PartitionSpec(n_local=n_local, seed=0),
                     protocol=protocols,
                     codec=("identity", "quantize", "dp_gaussian"),
                     quant_bits=(4, 8),
                     dp_sigma=(0.5, 1.5))
    t0 = time.time()
    runner = SweepRunner(CNN(), grid, *pool)
    res = runner.run()
    wall = round(time.time() - t0, 1)
    points = res.frames()
    payload = {
        "quick": quick,
        "grid_points": grid.size,
        "programs": runner.programs,
        "rounds": rounds,
        "local_iters": li,
        "wall_s": wall,
        "points": points,
    }
    # per-(protocol, codec family) frontier summary: best accuracy at
    # each uplink budget / privacy level (identity and quantize rows
    # repeat across the dp_sigma axis and vice versa — dedup on the
    # fields that matter for the family)
    seen, frontier = set(), []
    for row in points:
        fam = row["codec"]
        key = (row["protocol"], fam,
               row["quant_bits"] if fam == "quantize" else None,
               row["dp_sigma"] if fam == "dp_gaussian" else None)
        if key in seen:
            continue
        seen.add(key)
        frontier.append({
            "protocol": row["protocol"], "codec": fam,
            "quant_bits": row["quant_bits"] if fam == "quantize" else None,
            "dp_sigma": row["dp_sigma"] if fam == "dp_gaussian" else None,
            "final_acc": row["final_acc"],
            "uplink_bits": row["uplink_bits"],
            "uplink_bits_total": row["uplink_bits_total"],
            "dp_epsilon": row["dp_epsilon"],
        })
    payload["frontier"] = frontier
    print(f"frontier sweep: {grid.size} points, {runner.programs} "
          f"programs, wall={wall}s")
    for row in frontier:
        eps = row["dp_epsilon"]
        print(f"  {row['protocol']:8s} {row['codec']:12s} "
              f"bits={row['uplink_bits']:>9.0f} "
              f"eps={'-' if eps is None else f'{eps:.2f}'} "
              f"acc={row['final_acc']:.3f}")
    save_result("payload_frontier", payload)
    return payload


def main(quick=True):
    out = run()
    frontier = run_frontier(quick=quick)
    rows = []
    for proto in PROTOCOLS:
        v = out[proto]
        rows.append(f"payload/{proto},0,up={v['uplink_bits_steady']}"
                    f";ok={v['uplink_success_rate']:.3f}")
    r = out["ratios"]
    rows.append(f"payload/uplink_reduction_steady,0,"
                f"{r['fl_over_mix2fld_steady']:.1f}x")
    rows.append(f"payload/uplink_reduction_amortized_10r,0,"
                f"{r['fl_over_mix2fld_amortized_10r']:.1f}x")
    rows.append(f"payload/frontier,{frontier['wall_s']*1e6:.0f},"
                f"points={frontier['grid_points']}"
                f";programs={frontier['programs']}")
    return rows


if __name__ == "__main__":
    out = run()
    run_frontier(quick=False)
    print(out["ratios"])
