"""Sec. II-C / IV payload + latency accounting: uplink payload ratios
(the paper's "up to 42.4x" reduction) and per-round link latency under
the paper's exact channel parameters."""
from __future__ import annotations

import jax
import numpy as np

from repro.channel import ChannelConfig, payload_bits
from repro.channel.model import simulate_link

from .common import save_result

N_MOD = 12544
N_L = 10


def run():
    cfg = ChannelConfig()
    out = {}
    for proto in ("fl", "fd", "fld", "mixfld", "mix2fld"):
        up1, dn1 = payload_bits(proto, n_mod=N_MOD, n_labels=N_L,
                                sample_bits=6272, n_seed=10,
                                first_round=True)
        up, dn = payload_bits(proto, n_mod=N_MOD, n_labels=N_L,
                              first_round=False)
        lat_up, ok_up = simulate_link(jax.random.PRNGKey(0), cfg, up, True,
                                      2000)
        lat_dn, ok_dn = simulate_link(jax.random.PRNGKey(1), cfg, dn, False,
                                      2000)
        out[proto] = {
            "uplink_bits_first_round": up1,
            "uplink_bits_steady": up,
            "downlink_bits": dn,
            "uplink_success_rate": float(np.mean(np.asarray(ok_up))),
            "uplink_mean_latency_slots": float(np.mean(np.asarray(lat_up))),
            "downlink_success_rate": float(np.mean(np.asarray(ok_dn))),
        }
    fl_up = out["fl"]["uplink_bits_steady"]
    out["ratios"] = {
        "fl_over_fd_steady": fl_up / out["fd"]["uplink_bits_steady"],
        "fl_over_mix2fld_steady": fl_up / out["mix2fld"]["uplink_bits_steady"],
        "fl_over_mix2fld_first": fl_up /
            out["mix2fld"]["uplink_bits_first_round"],
    }
    save_result("payload_latency", out)
    return out


def main():
    out = run()
    rows = []
    for proto, v in out.items():
        if proto == "ratios":
            continue
        rows.append(f"payload/{proto},0,up={v['uplink_bits_steady']}"
                    f";ok={v['uplink_success_rate']:.3f}")
    r = out["ratios"]
    rows.append(f"payload/uplink_reduction_steady,0,"
                f"{r['fl_over_mix2fld_steady']:.1f}x")
    rows.append(f"payload/uplink_reduction_first_round,0,"
                f"{r['fl_over_mix2fld_first']:.1f}x")
    return rows


if __name__ == "__main__":
    print(main())
