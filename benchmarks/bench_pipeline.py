"""Double-buffered round pipeline benchmark: the async round program's
bitwise-equivalence gate plus its overlap headline.

Three results land in benchmarks/results/pipeline.json:

* ``serial_max_dev`` — max per-round record deviation between the
  strict-serial (``pipeline_depth=1``) and double-buffered (depth 2)
  runs of the same config.  Link draws are pure functions of
  ``(plan, key)``, so dispatch order must not change a single bit —
  gated at exactly 0.0 in check_regression.py.
* ``overlap_speedup`` — the steady-state rounds/s ratio the depth-2
  schedule exposes: ``serial_round / max(compute, channel)`` from the
  *measured* per-round channel-draw and residual-compute times.  The
  depth-2 schedule dispatches round p+1's draw while round p trains, so
  with a host core free for the XLA executor the slower of the two
  stages bounds the round; this metric is that bound, achieved on any
  multi-core host and machine-comparable because it is a ratio of
  same-host wall times.  The quick regime is tuned channel-heavy
  (t_max_slots sizes the per-link bernoulli matrix) so the bound sits
  near 1.6x — the >= 1.2x floor in check_regression.py catches an
  overlap collapse (e.g. a draw accidentally made state-dependent and
  serialized) with wide noise margin.
* ``wall_speedup_depth2`` — the directly measured depth1/depth2
  wall-clock ratio on THIS host, reported for context and ungated: a
  single-core CI container time-slices the executor and dispatch
  threads, so it measures ~1.0 there while multi-core hosts approach
  ``overlap_speedup``.

The roofline model's recommendation (``recommend_execution``) is
reported alongside: fed the measured component times it must pick
depth 2 in this regime, and its mesh shape is what the heterogeneous
2-D sweep below runs on (one compiled program per structural group —
``programs_per_group`` stays 1.0, same gate as bench_models).
"""
from __future__ import annotations

import sys
import time

import jax
import numpy as np

from repro.channel import ChannelConfig
from repro.core.program import ProgramOptions
from repro.core.protocols import FederatedConfig, FederatedTrainer
from repro.data import partition_iid, synthetic_images
from repro.models.mlp import MLPClassifier
from repro.roofline.analysis import recommend_execution
from repro.sweep import SweepRunner, engine_stats, make_grid

from .common import save_result

#: per-round record fields the serial-vs-async deviation is measured
#: over (host wall-clock measurements like compute_s are excluded —
#: they differ by scheduling, which is the whole point)
_DEV_KEYS = ("acc", "loss", "round_latency_s")
_EXACT_KEYS = ("round", "uplink_ok", "n_straggle")


def _history_dev(ref, got) -> float:
    dev = 0.0
    for a, b in zip(ref["records"], got["records"]):
        for k in _EXACT_KEYS:
            if a[k] != b[k]:
                dev = max(dev, 1.0)
        for k in _DEV_KEYS:
            dev = max(dev, abs(float(a[k]) - float(b[k])))
    if ref["converged_round"] != got["converged_round"]:
        dev = max(dev, 1.0)
    return dev


def _records(history) -> dict:
    rounds = len(history["acc"])
    return {
        "records": [
            {"round": p + 1,
             "acc": float(history["acc"][p]),
             "loss": float(history["loss"][p]),
             "round_latency_s": float(history["round_latency_s"][p]),
             "uplink_ok": int(history["uplink_ok"][p]),
             "n_straggle": int(history.get("n_straggle", [0] * rounds)[p])}
            for p in range(rounds)],
        "converged_round": history["converged_round"],
    }


def run(quick=False, rounds=None):
    rounds = rounds or (10 if quick else 20)
    D = 8
    x, y = synthetic_images(jax.random.PRNGKey(0), D * 40 + 200)
    dev_x, dev_y = partition_iid(np.asarray(x[: D * 40]),
                                 np.asarray(y[: D * 40]), D, 40, 10,
                                 seed=0)
    tx, ty = x[D * 40:], y[D * 40:]
    model = MLPClassifier(10, tuple(tx.shape[1:]))
    fc = FederatedConfig(protocol="fd", num_devices=D, local_iters=2,
                         local_batch=8, server_iters=1, server_batch=8,
                         max_rounds=rounds, seed=0)
    # balanced regime: the (D, t_max_slots) bernoulli matrix sizes the
    # link draw to roughly match the residual round compute, putting
    # the overlap bound near its 2x optimum — comfortably clear of the
    # 1.2x gate
    ch = ChannelConfig(num_devices=D, t_max_slots=30000,
                       compute_mean_s=0.05, deadline_s=0.25)
    tr = FederatedTrainer(model, fc, ch)

    def timed_run(depth):
        t0 = time.perf_counter()
        h = tr.run(dev_x, dev_y, tx, ty,
                   options=ProgramOptions(pipeline_depth=depth))
        return h, time.perf_counter() - t0

    tr.run(dev_x, dev_y, tx, ty)  # warm every jit cache

    h1, s1 = timed_run(1)
    h2, s2 = timed_run(2)
    serial_max_dev = _history_dev(_records(h1), _records(h2))

    # component times: the channel stage alone (serial dispatch+collect,
    # warm), and the residual round compute as serial-round minus it
    plan = tr.link_plan(tr.init_state().g_params, n_links=D)
    reps = 2 * rounds
    t0 = time.perf_counter()
    for i in range(reps):
        plan.draw(jax.random.fold_in(jax.random.PRNGKey(1), i),
                  first_round=False)
    channel_s = (time.perf_counter() - t0) / reps
    round_s = s1 / rounds
    compute_s = max(round_s - channel_s, 1e-9)
    overlap_speedup = round_s / max(compute_s, channel_s)

    rec = recommend_execution(1, D, avail=len(jax.devices()),
                              compute_s=compute_s, channel_s=channel_s)

    # heterogeneous sweep on the 2-D (grid x device) mesh: one compiled
    # program per structural group must survive the mesh option (the
    # grid shape the points allow degrades gracefully per group)
    engine_stats.reset()
    grid = make_grid(fc, ch, protocol=("fl", "fd", "mix2fld"),
                     eta=(0.01, 0.02))
    runner = SweepRunner(model, grid, dev_x, dev_y, tx, ty,
                         options=ProgramOptions(mesh_shape=(2, 4)))
    runner.run()
    groups = len(grid.program_groups())
    programs_per_group = engine_stats.programs / groups

    out = {
        "rounds": rounds,
        "num_devices": D,
        "t_max_slots": ch.t_max_slots,
        "quick": bool(quick),
        "serial_max_dev": serial_max_dev,
        "depth1_rounds_per_s": round(rounds / s1, 3),
        "depth2_rounds_per_s": round(rounds / s2, 3),
        "wall_speedup_depth2": round(s1 / s2, 4),
        "channel_ms_per_round": round(channel_s * 1e3, 3),
        "compute_ms_per_round": round(compute_s * 1e3, 3),
        "overlap_speedup": round(overlap_speedup, 4),
        "pipeline_stats_depth2": h2["pipeline"],
        "roofline_pipeline_depth": rec["pipeline_depth"],
        "roofline_mesh_shape": list(rec["mesh_shape"]),
        "roofline_rationale": rec["rationale"],
        "sweep_grid_points": grid.size,
        "sweep_groups": groups,
        "sweep_programs": engine_stats.programs,
        "programs_per_group": programs_per_group,
        "sweep_mesh_shapes": [list(p.mesh_shape)
                              for _, _, p in runner._programs],
    }
    save_result("pipeline", out)
    print(f"pipeline: {rounds} rounds serial_max_dev={serial_max_dev:g} "
          f"overlap_speedup={overlap_speedup:.2f}x "
          f"(channel {channel_s * 1e3:.1f}ms + compute "
          f"{compute_s * 1e3:.1f}ms per round, wall depth2 "
          f"{out['wall_speedup_depth2']:.2f}x) "
          f"roofline depth={rec['pipeline_depth']} "
          f"mesh={rec['mesh_shape']} "
          f"2-D sweep {engine_stats.programs} programs / {groups} groups")
    return out


def main(quick=True):
    out = run(quick=quick)
    return [
        f"pipeline/round_depth1,"
        f"{1e6 / max(out['depth1_rounds_per_s'], 1e-9):.0f},"
        f"serial_max_dev={out['serial_max_dev']:.1e}",
        f"pipeline/round_depth2,"
        f"{1e6 / max(out['depth2_rounds_per_s'], 1e-9):.0f},"
        f"overlap_speedup={out['overlap_speedup']:.2f}",
        f"pipeline/sweep_2d,0,"
        f"programs_per_group={out['programs_per_group']:.1f}",
    ]


if __name__ == "__main__":
    run(quick="--quick" in sys.argv[1:])
