"""Continuous-serving driver benchmark: round throughput with per-round
checkpointing on vs off, restore cost, and the resume-correctness
headline (restore the halfway checkpoint, re-run the tail, max per-round
record deviation vs the uninterrupted run).

The throughput gate is a *ratio* (checkpoint-on over checkpoint-off
rounds/s on the same host, same warmed jit caches), so machine speed
largely cancels — what it actually bounds is the relative cost of the
crash-safe save path (stage + fsync-free rename + retention GC) per
round.  ``restore_tail_max_dev`` is the benchmark-side twin of the
tests/test_service.py acceptance property and is gated at 1e-6
absolutely.  Numbers land in benchmarks/results/service.json.
"""
from __future__ import annotations

import shutil
import sys
import tempfile
import time

from repro.channel import ChannelConfig
from repro.core.protocols import FederatedConfig
from repro.launch.service import ChurnConfig, FederatedService
from repro.models.cnn import CNN

from .common import protocol_dataset, save_result

#: record fields the resume deviation is measured over (uplink_ok /
#: n_active are integers and must match exactly — folded in as 1.0 devs;
#: compute_s / cum_time_s are host wall-clock *measurements*, not
#: simulated quantities, so they are excluded like in test_service.py)
_DEV_KEYS = ("acc", "loss", "round_latency_s")
_EXACT_KEYS = ("round", "uplink_ok", "n_straggle", "n_active")


def _make(fc, ch, churn, data, ckpt_dir=None):
    svc = FederatedService(CNN(), fc, ch, churn=churn,
                           ckpt_dir=ckpt_dir, ckpt_every=1)
    return svc.bind_data(*data)


def _tail_dev(ref, got):
    dev = 0.0
    for a, b in zip(ref, got):
        for k in _EXACT_KEYS:
            if a[k] != b[k]:
                dev = max(dev, 1.0)
        for k in _DEV_KEYS:
            dev = max(dev, abs(float(a[k]) - float(b[k])))
    return dev


def run(quick=False, rounds=None):
    rounds = rounds or (4 if quick else 8)
    data = protocol_dataset(num_devices=4, per_device=150, n_test=500)
    fc = FederatedConfig(protocol="mix2fld", num_devices=4, local_iters=4,
                         local_batch=16, server_iters=4, server_batch=16,
                         max_rounds=rounds, n_seed=6, n_inverse=12,
                         seed=0)
    # churn + straggler regime: the service's whole feature surface is on
    ch = ChannelConfig(num_devices=4, p_up_dbm=40.0,
                       compute_mean_s=0.05, deadline_s=0.15)
    churn = ChurnConfig(p_active=0.75, min_active=2, seed=1)
    ckpt_dir = tempfile.mkdtemp(prefix="bench_fedsvc_")
    try:
        # one throwaway pass traces every cohort size the seeded churn
        # will draw (cohorts are a pure function of the round number),
        # so BOTH timed passes below run against warm jit caches —
        # without it the first pass absorbs the retraces and the ratio
        # measures compilation, not the checkpoint path
        _make(fc, ch, churn, data).run_rounds(rounds)

        # -- checkpoint-off throughput --
        off = _make(fc, ch, churn, data)
        t0 = time.perf_counter()
        off.run_rounds(rounds)
        off_s = time.perf_counter() - t0

        # -- checkpoint-on throughput (same rounds, per-round saves) --
        on = _make(fc, ch, churn, data, ckpt_dir=ckpt_dir)
        t0 = time.perf_counter()
        on.run_rounds(rounds)
        on_s = time.perf_counter() - t0
        total = rounds

        # -- serve one padded batch against the live model --
        t0 = time.perf_counter()
        preds = on.serve(data[2][: on.endpoint.batch_size - 3])
        serve_s = time.perf_counter() - t0

        # -- restore the halfway checkpoint, re-run the tail --
        mid = total // 2
        resumed = _make(fc, ch, churn, data, ckpt_dir=ckpt_dir)
        t0 = time.perf_counter()
        got = resumed.restore(step=mid)
        restore_s = time.perf_counter() - t0
        assert got == mid, (got, mid)
        tail = resumed.run_rounds(total - mid)
        tail_dev = _tail_dev(on.history[mid:], tail)

        out = {
            "rounds": rounds,
            "num_devices": 4,
            "quick": bool(quick),
            "p_active": churn.p_active,
            "nockpt_rounds_per_s": round(rounds / off_s, 3),
            "ckpt_rounds_per_s": round(rounds / on_s, 3),
            # host speed cancels in the ratio: it bounds the relative
            # per-round cost of the crash-safe checkpoint path
            "ckpt_on_off_ratio": round(off_s / on_s, 4),
            "restore_s": round(restore_s, 4),
            "serve_batch_us": round(serve_s * 1e6, 1),
            "served": int(preds.shape[0]),
            "tail_rounds": total - mid,
            "restore_tail_max_dev": tail_dev,
            # per-round accuracy under churn + straggler timeouts (the
            # EXPERIMENTS.md continuous-serving table)
            "rounds_detail": [
                {"round": r["round"], "acc": round(float(r["acc"]), 4),
                 "n_active": r["n_active"],
                 "n_straggle": r["n_straggle"],
                 "uplink_ok": r["uplink_ok"]}
                for r in on.history],
        }
        save_result("service", out)
        print(f"service: {rounds} rounds ckpt-off={off_s:.2f}s "
              f"ckpt-on={on_s:.2f}s (ratio {out['ckpt_on_off_ratio']:.2f}) "
              f"restore={restore_s*1e3:.0f}ms "
              f"tail dev={tail_dev:.2e} over {total - mid} rounds")
        return out
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


def main(quick=True):
    out = run(quick=quick)
    return [
        f"service/ckpt_on_{out['rounds']}r,"
        f"{1e6 / max(out['ckpt_rounds_per_s'], 1e-9):.0f},"
        f"on_off_ratio={out['ckpt_on_off_ratio']:.2f}",
        f"service/restore,{out['restore_s']*1e6:.0f},"
        f"tail_max_dev={out['restore_tail_max_dev']:.1e}",
    ]


if __name__ == "__main__":
    run(quick="--quick" in sys.argv[1:])
