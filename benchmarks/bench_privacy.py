"""Tables II & III reproduction: sample privacy vs mixing ratio lambda,
for Mixup (single device) and Mix2up (cross-device inverse mixup)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mixup import inverse_mixup, make_mixup_batch, mixup_pairs
from repro.core.privacy import sample_privacy

from .common import save_result

LAMBDAS = (0.001, 0.1, 0.2, 0.3, 0.4, 0.499)


def run(n_samples: int = 100, seed: int = 0, quick: bool = False):
    if quick:
        n_samples = min(n_samples, 40)
    from repro.data import synthetic_images
    key = jax.random.PRNGKey(seed)
    x, y = synthetic_images(key, 4000)
    x = x.reshape(x.shape[0], -1)

    tab2, tab3 = {}, {}
    for lam in LAMBDAS:
        # ---- Table II: Mixup privacy (vs own constituents) ----
        i, j = mixup_pairs(jax.random.fold_in(key, 1), y, n_samples, 10)
        mixed, _, (mi, ma) = make_mixup_batch(x, y, i, j, lam, 10)
        raws = jnp.stack([x[i], x[j]], axis=1)
        tab2[lam] = float(jnp.mean(sample_privacy(mixed, raws)))

        # ---- Table III: Mix2up privacy ----
        # device d mixes (a1: c1, a2: c2); device d' mixes (b1: c2, b2: c1)
        # with *different* raw samples (cross-device pairing, Sec. III-C)
        i2, j2 = mixup_pairs(jax.random.fold_in(key, 2), y, n_samples, 10)
        ka, kb = jax.random.split(jax.random.fold_in(key, 3))

        def pick_other(labels_wanted, exclude, k):
            g = jax.random.gumbel(k, (labels_wanted.shape[0], y.shape[0]))
            mask = (y[None, :] == labels_wanted[:, None]) & \
                (jnp.arange(y.shape[0])[None, :] != exclude[:, None])
            return jnp.argmax(jnp.where(mask, g, -jnp.inf), axis=1)

        i2b = pick_other(y[j2], j2, ka)   # device d': minor class = c2
        j2b = pick_other(y[i2], i2, kb)   # device d': major class = c1
        mixed1, _, _ = make_mixup_batch(x, y, i2, j2, lam, 10)
        mixed2, _, _ = make_mixup_batch(x, y, i2b, j2b, lam, 10)
        s1, s2 = inverse_mixup(mixed1, mixed2, lam)
        raws2 = jnp.stack([x[i2], x[j2], x[i2b], x[j2b]], axis=1)
        p1 = sample_privacy(s1, raws2)
        p2 = sample_privacy(s2, raws2)
        tab3[lam] = float((jnp.mean(p1) + jnp.mean(p2)) / 2)

    save_result("privacy_tables", {
        "mixup_tab2": tab2, "mix2up_tab3": tab3,
        "n_samples": n_samples, "quick": quick})
    return tab2, tab3


def main(quick=False):
    tab2, tab3 = run(quick=quick)
    rows = []
    for lam in LAMBDAS:
        rows.append(f"tab2/mixup_lam{lam},0,privacy={tab2[lam]:.3f}")
        rows.append(f"tab3/mix2up_lam{lam},0,privacy={tab3[lam]:.3f}")
    # paper's qualitative claims
    ok_monotone = all(tab2[LAMBDAS[i]] <= tab2[LAMBDAS[i + 1]] + 1e-6
                      for i in range(len(LAMBDAS) - 1))
    rows.append(f"tab2/monotone_in_lambda,0,{ok_monotone}")
    return rows


if __name__ == "__main__":
    print(main())
