"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def save_result(name: str, payload) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def time_call(fn, *args, repeats: int = 5, warmup: int = 2):
    """us/call of a jitted fn (blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def protocol_dataset(num_devices: int = 10, per_device: int = 500,
                     iid: bool = True, n_test: int = 1000, seed: int = 0):
    import jax.numpy as jnp

    from repro.data import partition_iid, partition_noniid, synthetic_images

    n = num_devices * per_device + n_test
    x, y = synthetic_images(jax.random.PRNGKey(seed), n)
    ntr = num_devices * per_device
    if iid:
        dev_x, dev_y = partition_iid(x[:ntr], y[:ntr], num_devices,
                                     per_device, 10, seed=seed)
    else:
        dev_x, dev_y = partition_noniid(x[:ntr], y[:ntr], num_devices,
                                        seed=seed)
    return dev_x, dev_y, jnp.asarray(x[ntr:]), jnp.asarray(y[ntr:])


def sample_pool(n_train: int, n_test: int = 1000, seed: int = 0):
    """Flat (pool_x, pool_y, test_x, test_y) for partitioned sweep grids
    (each grid point's PartitionSpec splits the pool itself)."""
    import jax.numpy as jnp

    from repro.data import synthetic_images

    x, y = synthetic_images(jax.random.PRNGKey(seed), n_train + n_test)
    return (np.asarray(x[:n_train]), np.asarray(y[:n_train]),
            jnp.asarray(x[n_train:]), jnp.asarray(y[n_train:]))
