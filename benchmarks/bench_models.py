"""Heterogeneous-architecture FD on the compiled sweep path: ONE
``SweepRunner`` call over protocol x model x task.

This is the workload the model/task registries exist for — and the one
FL structurally cannot express: the FD-family uplink exchanges only
(C, C) output tables, so a cohort of {cnn, mlp, transformer} clients
distills into one global model.  The benchmark records

* ``programs_per_group`` — compiled-program builds per structural
  (protocol, codec, cohort, model, task) group; the engine contract is
  exactly 1.0 (gated);
* ``het_gain_min``/``het_gain_mean`` — final accuracy of the mixed
  {cnn, mlp, transformer} cohort minus its single-WORST-architecture
  baseline, per (protocol, task) cell: distillation across
  architectures must not fall below the weakest homogeneous cohort
  (gated via ``het_gain_mean``);
* ``rounds_per_s_warm`` — warm whole-grid throughput (the compiled
  scans re-run without retracing).

Numbers land in benchmarks/results/models.json and are gated by
check_regression.py in the CI sweeps job.
"""
from __future__ import annotations

import sys
import time

from repro.channel import ChannelConfig
from repro.core.protocols import FederatedConfig
from repro.data.partition import PartitionSpec
from repro.sweep import SweepRunner, engine_stats, make_grid

from .common import save_result

PROTOCOLS = ("fd", "mix2fld")
SINGLETONS = ("cnn", "mlp", "transformer")
MIXED = "cnn+mlp+transformer"


def run(quick: bool = False):
    tasks = ("digits", "speech") if quick else ("digits", "cifar",
                                                "speech")
    rounds = 3 if quick else 6
    fc = FederatedConfig(protocol="fd", num_devices=4, local_iters=6,
                         local_batch=16, server_iters=4, server_batch=16,
                         max_rounds=rounds, n_seed=6, n_inverse=12,
                         eps=0.0, seed=0)
    ch = ChannelConfig(num_devices=4, p_up_dbm=40.0)
    part = PartitionSpec(scheme="iid", n_local=150, seed=0)

    grid = make_grid(fc, ch, part, protocol=PROTOCOLS,
                     model=SINGLETONS + (MIXED,), task=tasks)
    groups = len(grid.program_groups())

    engine_stats.reset()
    t0 = time.perf_counter()
    runner = SweepRunner(None, grid)   # registry-built models, per-task
    res = runner.run()                 # pools — the ONE heterogeneous call
    cold_s = time.perf_counter() - t0
    res = runner.run()                 # warm: compiled scans re-execute
    programs_per_group = engine_stats.programs / groups

    # mixed-cohort gain over the single-worst-architecture baseline,
    # per (protocol, task) cell of the grid
    final = {}
    for g in range(grid.size):
        h = res.history(g)
        final[(h["protocol"], h["model"], h["task"])] = h["final_acc"]
    gains, cells = [], {}
    for p in PROTOCOLS:
        for t in tasks:
            worst = min(final[(p, m, t)] for m in SINGLETONS)
            gain = final[(p, MIXED, t)] - worst
            cells[f"{p}/{t}"] = {
                "mixed": round(final[(p, MIXED, t)], 4),
                "worst_singleton": round(worst, 4),
                "gain": round(gain, 4),
                **{m: round(final[(p, m, t)], 4) for m in SINGLETONS},
            }
            gains.append(gain)

    out = {
        "grid_points": grid.size,
        "rounds": rounds,
        "tasks": list(tasks),
        "quick": bool(quick),
        "groups": groups,
        "programs": engine_stats.programs,
        "programs_per_group": programs_per_group,
        "traces": engine_stats.traces,
        "cold_s": round(cold_s, 2),
        "warm_s": round(res.wall_s, 4),
        "rounds_per_s_warm": round(grid.size * rounds / res.wall_s, 3),
        "het_gain_min": round(min(gains), 4),
        "het_gain_mean": round(sum(gains) / len(gains), 4),
        "cells": cells,
    }
    save_result("models", out)
    print(f"models: {grid.size} points in {groups} programs "
          f"({programs_per_group:.1f} per group), cold {cold_s:.1f}s, "
          f"warm {res.wall_s:.2f}s "
          f"({out['rounds_per_s_warm']:.1f} rounds/s)")
    for cell, v in cells.items():
        print(f"  {cell}: mixed={v['mixed']:.3f} "
              f"worst_singleton={v['worst_singleton']:.3f} "
              f"gain={v['gain']:+.3f}")
    return out


def main(quick=True):
    out = run(quick=quick)
    rows = [
        (f"models/het_grid,{out['warm_s']*1e6:.0f},"
         f"rounds_per_s={out['rounds_per_s_warm']:.1f};"
         f"programs_per_group={out['programs_per_group']:.1f}"),
        (f"models/het_gain,0,min={out['het_gain_min']:+.3f};"
         f"mean={out['het_gain_mean']:+.3f}"),
    ]
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv[1:])
