"""Fig. 2 reproduction: learning curves of FL / FD / MixFLD / Mix2FLD
under asymmetric vs symmetric channels, IID vs non-IID data.

Rewritten on the compiled sweep engine: for each (protocol, data split)
the two channel regimes run as ONE program — a G=2 sweep over the
``p_up_dbm`` axis — instead of two re-traced trainer loops.  Reduced
iteration counts (documented) keep the CPU container tractable; the
paper's *relative* claims are what EXPERIMENTS.md reports.
"""
from __future__ import annotations

import time

from repro.channel import ChannelConfig
from repro.core.protocols import FederatedConfig
from repro.models.cnn import CNN
from repro.sweep import SweepRunner, make_grid

from .common import protocol_dataset, save_result

PROTOCOLS = ("fl", "fd", "mixfld", "mix2fld")
P_UP = {"asym": 23.0, "sym": 40.0}


def run(local_iters=150, server_iters=150, max_rounds=8, num_devices=10,
        quick=False):
    p_up = dict(P_UP)
    if quick:
        local_iters, server_iters, max_rounds, num_devices = 15, 15, 2, 5
        # at D=5 each device gets enough FDMA bandwidth that 23 dBm still
        # decodes the FL payload; drop the asym point until the uplink
        # actually outages, so the quick table shows the channel effect
        p_up["asym"] = 15.0
    results = {}
    for iid in (True, False):
        dev = protocol_dataset(num_devices=num_devices, iid=iid)
        for proto in PROTOCOLS:
            base = FederatedConfig(
                protocol=proto, num_devices=num_devices,
                local_iters=local_iters, local_batch=32,
                server_iters=server_iters, server_batch=32,
                max_rounds=max_rounds, seed=1)
            ch = ChannelConfig(num_devices=num_devices)
            grid = make_grid(base, ch, p_up_dbm=tuple(p_up.values()))
            t0 = time.time()
            res = SweepRunner(CNN(), grid, *dev).run()
            wall = round(time.time() - t0, 1)
            for g, chan in enumerate(p_up):
                h = res.history(g)
                key = f"{proto}_{'iid' if iid else 'noniid'}_{chan}"
                results[key] = {
                    "acc": h["acc"],
                    "cum_time_s": h["cum_time_s"],
                    "uplink_ok": h["uplink_ok"],
                    "converged_round": h["converged_round"],
                    "wall_s": wall,  # one sweep ran both channel regimes
                }
                print(f"{key}: final_acc={h['acc'][-1]:.3f} "
                      f"up_ok={h['uplink_ok']}")
    save_result("protocols_fig2", results)
    return results


def main(quick=True):
    res = run(quick=quick)
    rows = []
    for k, v in res.items():
        rows.append(f"fig2/{k},{v['wall_s']*1e6:.0f},"
                    f"final_acc={v['acc'][-1]:.4f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
