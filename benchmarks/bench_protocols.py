"""Fig. 2 / Table I reproduction on the heterogeneous sweep engine.

The paper's headline comparisons — FL / FD / FLD / MixFLD / Mix2FLD
under asymmetric vs symmetric channels, IID vs non-IID data — are ONE
heterogeneous grid: ``protocol`` x ``partition`` x ``p_up_dbm``.  A
single ``SweepRunner`` call compiles it into one vmapped program per
distinct protocol (the protocols differ structurally; everything else
batches), builds each distinct device partition exactly once, and preps
seeds once per (FLD protocol, partition) seed group.  The per-point loop
this replaces re-traced one ``FederatedTrainer`` per (protocol, split,
channel) cell — 20 traces instead of 5.

Reduced iteration counts (documented) keep the CPU container tractable;
the paper's *relative* claims are what EXPERIMENTS.md reports.
"""
from __future__ import annotations

import time

from repro.channel import ChannelConfig
from repro.core.protocols import PROTOCOLS, FederatedConfig
from repro.data import PartitionSpec
from repro.models.cnn import CNN
from repro.sweep import SweepRunner, make_grid

from .common import sample_pool, save_result

P_UP = {"asym": 23.0, "sym": 40.0}


def run(local_iters=150, server_iters=150, max_rounds=8, num_devices=10,
        n_local=500, quick=False):
    p_up = dict(P_UP)
    if quick:
        local_iters, server_iters, max_rounds, num_devices, n_local = \
            15, 15, 2, 5, 100
        # at D=5 each device gets enough FDMA bandwidth that 23 dBm still
        # decodes the FL payload; drop the asym point until the uplink
        # actually outages, so the quick table shows the channel effect
        p_up["asym"] = 15.0
    pool = sample_pool(num_devices * n_local, seed=0)
    base = FederatedConfig(
        protocol="mix2fld", num_devices=num_devices,
        local_iters=local_iters, local_batch=32,
        server_iters=server_iters, server_batch=32,
        max_rounds=max_rounds, seed=1)
    ch = ChannelConfig(num_devices=num_devices)
    grid = make_grid(base, ch, PartitionSpec(n_local=n_local, seed=0),
                     protocol=PROTOCOLS,
                     partition=("iid", "noniid"),
                     p_up_dbm=tuple(p_up.values()))
    t0 = time.time()
    runner = SweepRunner(CNN(), grid, *pool)
    res = runner.run()
    wall = round(time.time() - t0, 1)
    chan_of = {v: k for k, v in p_up.items()}
    results = {}
    for g, label in enumerate(grid.labels()):
        h = res.history(g)
        key = (f"{label['protocol']}_{label['partition']}"
               f"_{chan_of[label['p_up_dbm']]}")
        results[key] = {
            "acc": h["acc"],
            "cum_time_s": h["cum_time_s"],
            "uplink_ok": h["uplink_ok"],
            "converged_round": h["converged_round"],
            # one heterogeneous sweep ran every (protocol, split,
            # channel) cell; programs = #distinct protocols
            "wall_s": wall,
            "programs": runner.programs,
        }
        print(f"{key}: final_acc={h['acc'][-1]:.3f} "
              f"up_ok={h['uplink_ok']}")
    print(f"heterogeneous sweep: {grid.size} points, "
          f"{runner.programs} programs, "
          f"seed prep {runner.seed_prep_stats}, wall={wall}s")
    save_result("protocols_fig2", results)
    return results


def main(quick=True):
    res = run(quick=quick)
    rows = []
    for k, v in res.items():
        rows.append(f"fig2/{k},{v['wall_s']*1e6:.0f},"
                    f"final_acc={v['acc'][-1]:.4f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
