"""Fig. 2 reproduction: learning curves of FL / FD / MixFLD / Mix2FLD
under asymmetric vs symmetric channels, IID vs non-IID data.

Reduced iteration counts (documented) keep the CPU container tractable;
the paper's *relative* claims are what EXPERIMENTS.md reports.
"""
from __future__ import annotations

import time

from repro.channel import ChannelConfig
from repro.core.protocols import FederatedConfig, FederatedTrainer
from repro.models.cnn import CNN

from .common import protocol_dataset, save_result

PROTOCOLS = ("fl", "fd", "mixfld", "mix2fld")


def run(local_iters=150, server_iters=150, max_rounds=8, num_devices=10,
        quick=False):
    if quick:
        local_iters, server_iters, max_rounds, num_devices = 40, 40, 2, 5
    results = {}
    for iid in (True, False):
        dev = protocol_dataset(num_devices=num_devices, iid=iid)
        for sym in (False, True):
            ch = ChannelConfig(num_devices=num_devices,
                               p_up_dbm=40.0 if sym else 23.0)
            for proto in PROTOCOLS:
                fc = FederatedConfig(
                    protocol=proto, num_devices=num_devices,
                    local_iters=local_iters, local_batch=32,
                    server_iters=server_iters, server_batch=32,
                    max_rounds=max_rounds, seed=1)
                t0 = time.time()
                h = FederatedTrainer(CNN(), fc, ch).run(*dev)
                key = f"{proto}_{'iid' if iid else 'noniid'}_" \
                      f"{'sym' if sym else 'asym'}"
                results[key] = {
                    "acc": h["acc"],
                    "cum_time_s": h["cum_time_s"],
                    "uplink_ok": h["uplink_ok"],
                    "converged_round": h["converged_round"],
                    "wall_s": round(time.time() - t0, 1),
                }
                print(f"{key}: final_acc={h['acc'][-1]:.3f} "
                      f"up_ok={h['uplink_ok']}")
    save_result("protocols_fig2", results)
    return results


def main(quick=True):
    res = run(quick=quick)
    rows = []
    for k, v in res.items():
        rows.append(f"fig2/{k},{v['wall_s']*1e6:.0f},"
                    f"final_acc={v['acc'][-1]:.4f}")
    return rows


if __name__ == "__main__":
    run(quick=False)
