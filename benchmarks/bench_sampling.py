"""Client-sampling throughput on the compiled grid path: rounds/s vs
``sample_ratio`` at a fixed device pool.

The tentpole claim of the sampling layer is that per-round cost scales
with the *cohort*, not the pool — a ``sample_ratio=0.25`` point trains
a quarter of the devices per round and the compiled scan's device axis
shrinks to match.  This benchmark measures warm rounds/s of a
single-point sweep at each ratio over one fixed pool (the quick regime
CI runs: D=256; full: D=4096) and records

* ``rounds_per_s`` per ratio and the ``speedup_*`` ratios against the
  full-participation run (wall-clock ratios, so host speed cancels —
  gated by check_regression.py against the committed baseline);
* ``ratio1_max_dev`` — max |acc deviation| of a ``sample_ratio=1.0``
  (non-default ``sample_seed``) run against the unsampled config: the
  full-ratio path must be the SAME compiled program, so this is gated
  at bitwise zero.

The model is a ~500-parameter linear probe: at pool scale the stacked
per-device parameters, not the FLOPs, are what the cohort gather must
keep off the round body, and a tiny model keeps the full pool tractable
on the CI host.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.channel import ChannelConfig
from repro.core.protocols import FederatedConfig
from repro.data import partition_iid, synthetic_images
from repro.sweep import SweepRunner, make_grid

from .common import save_result

RATIOS = (1.0, 0.5, 0.25)


class TinyNet:
    """Linear probe over 4x4-average-pooled images (49 features)."""

    def init(self, key):
        k, _ = jax.random.split(key)
        return {"w": jax.random.normal(k, (49, 10)) * 0.1,
                "b": jnp.zeros((10,))}

    def apply(self, params, x):
        b = x.shape[0]
        pooled = x[..., 0].reshape(b, 7, 4, 7, 4).mean(axis=(2, 4))
        return pooled.reshape(b, 49) @ params["w"] + params["b"]


def _pool(num_devices: int, per_device: int = 10, n_test: int = 200,
          seed: int = 0):
    n = num_devices * per_device + n_test
    x, y = synthetic_images(jax.random.PRNGKey(seed), n)
    ntr = num_devices * per_device
    dev_x, dev_y = partition_iid(np.asarray(x[:ntr]), np.asarray(y[:ntr]),
                                 num_devices, per_device, 10, seed=seed)
    return dev_x, dev_y, jnp.asarray(x[ntr:]), jnp.asarray(y[ntr:])


def _fc(num_devices: int, max_rounds: int, **kw):
    return FederatedConfig(protocol="fd", num_devices=num_devices,
                           local_iters=1, local_batch=4, server_iters=1,
                           server_batch=4, max_rounds=max_rounds, seed=0,
                           **kw)


def run(pool: int = 4096, max_rounds: int = 3, quick: bool = False):
    if quick:
        pool = 256
    data = _pool(pool)
    ch = ChannelConfig(num_devices=pool, p_up_dbm=40.0)

    per_ratio = {}
    accs = {}
    for ratio in RATIOS:
        fc = _fc(pool, max_rounds, sample_ratio=ratio, sample_seed=123)
        grid = make_grid(fc, ch, eta=(0.01,))
        t0 = time.perf_counter()
        runner = SweepRunner(TinyNet(), grid, *data)
        res = runner.run()
        cold_s = time.perf_counter() - t0
        res = runner.run()  # warm: reuses the compiled scan
        per_ratio[ratio] = {
            "cohort": fc.cohort_size(),
            "cold_s": round(cold_s, 3),
            "warm_s": round(res.wall_s, 4),
            "rounds_per_s": round(max_rounds / res.wall_s, 3),
        }
        accs[ratio] = res.acc.copy()
        print(f"sample_ratio={ratio}: cohort={fc.cohort_size()}/{pool} "
              f"warm={res.wall_s:.3f}s "
              f"rounds/s={per_ratio[ratio]['rounds_per_s']:.2f}")

    # the full-ratio point must BE the unsampled program: bitwise check
    res0 = SweepRunner(TinyNet(), make_grid(_fc(pool, max_rounds), ch,
                                            eta=(0.01,)), *data).run()
    ratio1_max_dev = float(np.max(np.abs(accs[1.0] - res0.acc)))

    rps = {r: per_ratio[r]["rounds_per_s"] for r in RATIOS}
    out = {
        "pool": pool,
        "rounds": max_rounds,
        "quick": bool(quick),
        "ratios": {str(r): per_ratio[r] for r in RATIOS},
        "speedup_050": round(rps[0.5] / rps[1.0], 3),
        "speedup_025": round(rps[0.25] / rps[1.0], 3),
        "ratio1_max_dev": ratio1_max_dev,
    }
    save_result("sampling", out)
    print(f"sampling at D={pool}: q=0.5 {out['speedup_050']:.2f}x, "
          f"q=0.25 {out['speedup_025']:.2f}x vs full participation; "
          f"ratio1 dev={ratio1_max_dev:g}")
    return out


def main(quick=True):
    out = run(quick=quick)
    rows = []
    for r, v in out["ratios"].items():
        rows.append(f"sampling/q{r}_D{out['pool']},"
                    f"{v['warm_s']*1e6:.0f},"
                    f"rounds_per_s={v['rounds_per_s']:.2f}")
    rows.append(f"sampling/speedup_D{out['pool']},0,"
                f"q050={out['speedup_050']:.2f}x;"
                f"q025={out['speedup_025']:.2f}x")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv[1:])
