"""Kernel micro-benchmarks (interpret-mode correctness timing on CPU;
on TPU these time the Mosaic kernels) + oracle agreement."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.distill_loss import distill_loss_pallas
from repro.kernels.mixup_kernel import mixup_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

from .common import save_result, time_call


def main():
    rows = []
    k = jax.random.PRNGKey(0)

    # mixup
    a = jax.random.normal(k, (512, 784))
    b = jax.random.normal(jax.random.fold_in(k, 1), (512, 784))
    la = jnp.full((512,), 0.3)
    us = time_call(lambda: mixup_pallas(a, b, la, 1 - la))
    err = float(jnp.max(jnp.abs(mixup_pallas(a, b, la, 1 - la) -
                                ref.mixup_ref(a, b, la, 1 - la))))
    rows.append(f"kernel/mixup_512x784,{us:.0f},maxerr={err:.2e}")

    # distill loss
    logits = jax.random.normal(k, (1024, 10))
    labels = jax.random.randint(jax.random.fold_in(k, 2), (1024,), 0, 10)
    g = jax.nn.softmax(jax.random.normal(jax.random.fold_in(k, 3),
                                         (1024, 10)))
    us = time_call(lambda: distill_loss_pallas(logits, labels, g, 0.01))
    err = float(jnp.max(jnp.abs(
        distill_loss_pallas(logits, labels, g, 0.01) -
        ref.distill_loss_ref(logits, labels, g, 0.01))))
    rows.append(f"kernel/distill_loss_1024x10,{us:.0f},maxerr={err:.2e}")

    # ssd scan
    xdt = jax.random.normal(k, (8, 256, 32)) * 0.3
    B = jax.random.normal(jax.random.fold_in(k, 4), (8, 256, 16)) * 0.3
    C = jax.random.normal(jax.random.fold_in(k, 5), (8, 256, 16)) * 0.3
    dA = -jnp.abs(jax.random.normal(jax.random.fold_in(k, 6), (8, 256)))
    us = time_call(lambda: ssd_scan_pallas(xdt, B, C, dA, chunk=64),
                   repeats=2, warmup=1)
    err = float(jnp.max(jnp.abs(ssd_scan_pallas(xdt, B, C, dA, chunk=64) -
                                ref.ssd_ref(xdt, B, C, dA))))
    rows.append(f"kernel/ssd_scan_8x256,{us:.0f},maxerr={err:.2e}")

    save_result("kernels", {"rows": rows})
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
